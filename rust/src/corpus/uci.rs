//! UCI "Bag of Words" loader — the on-disk format of the paper's corpora
//! (ENRON, NIPS, NYTIMES, PUBMED at archive.ics.uci.edu/ml/datasets/bag+of+words).
//!
//! `docword.*.txt` layout:
//! ```text
//! D
//! W
//! NNZ
//! docID wordID count      # 1-based ids, one triplet per line
//! ...
//! ```
//! plus an optional `vocab.*.txt` with one word per line (line i = word id
//! i, 1-based).
//!
//! The loader is streaming-friendly: it reads line by line and never
//! materializes more than the CSR arrays, so PUBMED-scale files are bound
//! by the output size, not parse overhead.

use std::fs::File;
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use super::vocab::Vocabulary;
use super::{Corpus, DocWordMatrix};

/// Parse a `docword` stream. The corpus name is only used for reporting.
pub fn read_docword<R: BufRead>(name: &str, reader: R) -> anyhow::Result<Corpus> {
    let mut lines = reader.lines();
    let mut next_header = || -> anyhow::Result<usize> {
        loop {
            let line = lines
                .next()
                .ok_or_else(|| anyhow::anyhow!("unexpected EOF in header"))??;
            let t = line.trim();
            if !t.is_empty() {
                return Ok(t.parse::<usize>()?);
            }
        }
    };
    let n_docs = next_header()?;
    let n_words = next_header()?;
    let nnz = next_header()?;

    let mut doc_ptr = Vec::with_capacity(n_docs + 1);
    let mut word_ids = Vec::with_capacity(nnz);
    let mut counts = Vec::with_capacity(nnz);
    doc_ptr.push(0u32);
    let mut current_doc = 1usize; // 1-based in the file

    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() {
            continue;
        }
        let mut it = t.split_ascii_whitespace();
        let d: usize = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("short line: {t}"))?
            .parse()?;
        let w: usize = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("short line: {t}"))?
            .parse()?;
        let c: f32 = it
            .next()
            .ok_or_else(|| anyhow::anyhow!("short line: {t}"))?
            .parse()?;
        if d < current_doc {
            anyhow::bail!("docword file not sorted by document ({d} < {current_doc})");
        }
        if w == 0 || w > n_words {
            anyhow::bail!("word id {w} out of range 1..={n_words}");
        }
        while current_doc < d {
            doc_ptr.push(word_ids.len() as u32);
            current_doc += 1;
        }
        word_ids.push((w - 1) as u32);
        counts.push(c);
    }
    while current_doc <= n_docs {
        doc_ptr.push(word_ids.len() as u32);
        current_doc += 1;
    }
    if word_ids.len() != nnz {
        anyhow::bail!("NNZ mismatch: header says {nnz}, parsed {}", word_ids.len());
    }
    Ok(Corpus::new(
        name,
        DocWordMatrix { n_docs, n_words, doc_ptr, word_ids, counts },
    ))
}

/// Load `docword.<name>.txt`.
pub fn load_docword(path: &Path) -> anyhow::Result<Corpus> {
    let name = path
        .file_stem()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "corpus".into());
    let f = File::open(path)?;
    read_docword(&name, BufReader::new(f))
}

/// Load a `vocab.<name>.txt` word list.
pub fn load_vocab(path: &Path) -> anyhow::Result<Vocabulary> {
    let f = File::open(path)?;
    let mut v = Vocabulary::new();
    for line in BufReader::new(f).lines() {
        let line = line?;
        v.intern(line.trim());
    }
    Ok(v)
}

/// Write a corpus in docword format (round-trip support; used by tests and
/// by `expfig --export` so runs can be reproduced outside this crate).
pub fn write_docword<W: Write>(corpus: &Corpus, mut out: W) -> anyhow::Result<()> {
    writeln!(out, "{}", corpus.n_docs())?;
    writeln!(out, "{}", corpus.n_words())?;
    writeln!(out, "{}", corpus.nnz())?;
    for d in 0..corpus.n_docs() {
        for (w, c) in corpus.docs.iter_doc(d) {
            writeln!(out, "{} {} {}", d + 1, w + 1, c as u64)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const SAMPLE: &str = "3\n4\n5\n1 1 2\n1 3 1\n2 2 3\n3 4 5\n3 1 1\n";

    #[test]
    fn parses_header_and_triplets() {
        let c = read_docword("t", Cursor::new(SAMPLE)).unwrap();
        assert_eq!(c.n_docs(), 3);
        assert_eq!(c.n_words(), 4);
        assert_eq!(c.nnz(), 5);
        assert_eq!(c.docs.doc_words(0), &[0, 2]);
        assert_eq!(c.docs.doc_counts(1), &[3.0]);
        assert_eq!(c.docs.doc_words(2), &[3, 0]);
        assert_eq!(c.n_tokens(), 12.0);
    }

    #[test]
    fn handles_empty_documents() {
        // doc 2 has no entries
        let s = "3\n2\n2\n1 1 1\n3 2 4\n";
        let c = read_docword("t", Cursor::new(s)).unwrap();
        assert_eq!(c.docs.doc_words(1), &[] as &[u32]);
        assert_eq!(c.docs.doc_counts(2), &[4.0]);
    }

    #[test]
    fn rejects_bad_word_ids() {
        let s = "1\n2\n1\n1 3 1\n";
        assert!(read_docword("t", Cursor::new(s)).is_err());
    }

    #[test]
    fn rejects_unsorted_docs() {
        let s = "2\n2\n2\n2 1 1\n1 1 1\n";
        assert!(read_docword("t", Cursor::new(s)).is_err());
    }

    #[test]
    fn rejects_nnz_mismatch() {
        let s = "1\n2\n5\n1 1 1\n";
        assert!(read_docword("t", Cursor::new(s)).is_err());
    }

    #[test]
    fn write_read_round_trip() {
        let c = read_docword("t", Cursor::new(SAMPLE)).unwrap();
        let mut buf = Vec::new();
        write_docword(&c, &mut buf).unwrap();
        let c2 = read_docword("t", Cursor::new(buf)).unwrap();
        assert_eq!(c.docs, c2.docs);
    }
}
