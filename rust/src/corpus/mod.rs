//! Corpus substrate: sparse document-word matrices, the UCI bag-of-words
//! loader (the format of the paper's ENRON / NIPS / NYTIMES / PUBMED
//! sets), a synthetic LDA corpus generator (our substitute for those
//! corpora — see DESIGN.md §4), and the open-vocabulary manager used by
//! lifelong streams.

pub mod sparse;
pub mod synthetic;
pub mod uci;
pub mod vocab;

pub use sparse::{DocWordMatrix, VocabMajorMatrix};

/// A corpus: a doc-major sparse matrix plus bookkeeping.
#[derive(Debug, Clone)]
pub struct Corpus {
    /// Doc-major sparse document-word matrix.
    pub docs: DocWordMatrix,
    /// Human-readable name (used by the experiment harness for reporting).
    pub name: String,
}

impl Corpus {
    pub fn new(name: impl Into<String>, docs: DocWordMatrix) -> Self {
        Self { docs, name: name.into() }
    }

    pub fn n_docs(&self) -> usize {
        self.docs.n_docs
    }

    pub fn n_words(&self) -> usize {
        self.docs.n_words
    }

    pub fn nnz(&self) -> usize {
        self.docs.nnz()
    }

    pub fn n_tokens(&self) -> f64 {
        self.docs.total_tokens()
    }

    /// Split into (train, test) by documents; `test_docs` go to the test
    /// side, mirroring the paper's Table 4 splits. Deterministic in `seed`.
    pub fn split(&self, test_docs: usize, seed: u64) -> (Corpus, Corpus) {
        let mut order: Vec<usize> = (0..self.n_docs()).collect();
        let mut rng = crate::util::Rng::new(seed);
        rng.shuffle(&mut order);
        let test_set: std::collections::HashSet<usize> =
            order.into_iter().take(test_docs.min(self.n_docs())).collect();
        let mut train_docs: Vec<Vec<(u32, f32)>> = Vec::new();
        let mut test_docs_v: Vec<Vec<(u32, f32)>> = Vec::new();
        for d in 0..self.n_docs() {
            let row: Vec<(u32, f32)> = self.docs.iter_doc(d).collect();
            if test_set.contains(&d) {
                test_docs_v.push(row);
            } else {
                train_docs.push(row);
            }
        }
        let train_refs: Vec<&[(u32, f32)]> =
            train_docs.iter().map(|r| r.as_slice()).collect();
        let test_refs: Vec<&[(u32, f32)]> =
            test_docs_v.iter().map(|r| r.as_slice()).collect();
        let train = DocWordMatrix::from_rows(self.n_words(), &train_refs);
        let test = DocWordMatrix::from_rows(self.n_words(), &test_refs);
        (
            Corpus::new(format!("{}-train", self.name), train),
            Corpus::new(format!("{}-test", self.name), test),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Corpus {
        // 4 docs over 5 words.
        let rows: Vec<Vec<(u32, f32)>> = vec![
            vec![(0, 2.0), (1, 1.0)],
            vec![(1, 3.0), (4, 1.0)],
            vec![(2, 1.0)],
            vec![(0, 1.0), (3, 2.0), (4, 4.0)],
        ];
        let refs: Vec<&[(u32, f32)]> = rows.iter().map(|r| r.as_slice()).collect();
        Corpus::new("tiny", DocWordMatrix::from_rows(5, &refs))
    }

    #[test]
    fn split_preserves_mass_and_counts() {
        let c = tiny();
        let (train, test) = c.split(1, 0);
        assert_eq!(train.n_docs(), 3);
        assert_eq!(test.n_docs(), 1);
        assert_eq!(train.n_words(), 5);
        let total = c.n_tokens();
        assert!((train.n_tokens() + test.n_tokens() - total).abs() < 1e-9);
    }

    #[test]
    fn split_is_deterministic() {
        let c = tiny();
        let (a1, _) = c.split(2, 7);
        let (a2, _) = c.split(2, 7);
        assert_eq!(a1.docs.word_ids, a2.docs.word_ids);
    }
}
