//! Snapshot-isolated serving: concurrent unseen-document inference over
//! a live training loop.
//!
//! The paper's FOEM "infers the topic distribution from previously
//! unseen documents incrementally with constant memory" — [`crate::em::infer`]
//! is that engine, and this module is the layer that *serves* it while a
//! trainer keeps mutating the model (the ROADMAP's "heavy traffic" north
//! star). It is the first place in the crate where training-side
//! mutation and read-side traffic coexist, and the whole design reduces
//! that to one rule: **readers never see a mutable model** —
//!
//! * [`ModelRegistry`] — the trainer periodically publishes an immutable,
//!   epoch-tagged [`ModelSnapshot`] (one store column-snapshot read via
//!   `OnlineLda::eval_view`, wrapped in an `Arc`, installed with an
//!   atomic swap). Old epochs retire by reference count the moment
//!   their last pinned reader drops.
//! * [`Server`] / request batcher — incoming documents coalesce on a
//!   bounded queue (backpressure) into minibatches, which a persistent
//!   dispatcher fans out over [`crate::exec::ParallelExecutor::run_ranged`]
//!   workers running the scheduled [`crate::em::infer`] engine (scratch
//!   from the grow-only [`crate::exec::scratch`] pool). Each response
//!   carries per-doc theta, the doc's perplexity under the pinned model,
//!   and its latency; [`ServeReport`] aggregates docs/sec and p50/p99.
//!
//! **Epoch-pinned determinism.** A request pinned to epoch `E` returns
//! bit-identical `(theta, perplexity)` to an offline
//! [`crate::em::infer::fold_in`] run against that snapshot — batching,
//! pool size and concurrent publishing cannot reach the numerics because
//! each request folds in serially (`n_workers = 1`) with its own seed
//! against frozen state. Asserted in `tests/serve_equivalence.rs`;
//! see `rust/DESIGN.md` §10 for the full argument.
//!
//! # Examples
//!
//! Publish a model and serve a request against it:
//!
//! ```
//! use foem::em::{EvalPhiView, PhiStats};
//! use foem::serve::{ModelRegistry, ServeConfig, Server};
//! use foem::LdaParams;
//! use std::sync::Arc;
//!
//! // A (tiny, untrained) model: uniform mass over 4 topics × 8 words.
//! let (k, w) = (4, 8);
//! let mut phi = PhiStats::zeros(k, w);
//! for word in 0..w {
//!     phi.add_to_word(word, &vec![0.1; k]);
//! }
//! let words: Vec<u32> = (0..w as u32).collect();
//! let registry = Arc::new(ModelRegistry::new());
//! registry.publish(
//!     EvalPhiView::from_dense(&phi, &words),
//!     LdaParams::paper_defaults(k),
//! );
//!
//! let server = Server::start(Arc::clone(&registry), ServeConfig::default());
//! let pending = server.submit(vec![(0, 2.0), (3, 1.0)], 7).unwrap();
//! let resp = pending.wait().unwrap();
//! assert_eq!(resp.epoch, 1);
//! assert_eq!(resp.theta.len(), k);
//!
//! let report = server.shutdown();
//! assert_eq!(report.docs, 1);
//! ```

mod batcher;
mod registry;

pub use batcher::{InferResponse, PendingResponse, ServeReport, Server};
pub use registry::{ModelRegistry, ModelSnapshot};

use crate::em::infer::FoldInConfig;

/// Serving policy: queueing, batching, worker fan-out and the fold-in
/// protocol every request runs. Built from the run configuration by
/// [`crate::coordinator::config::RunConfig::serve_config`] (the
/// `serve_*` knobs).
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Most requests coalesced into one dispatched batch.
    pub max_batch_docs: usize,
    /// Bound of the request queue — the backpressure knob:
    /// [`Server::submit`] blocks and [`Server::try_submit`] fails once
    /// this many requests are pending.
    pub queue_docs: usize,
    /// Worker threads a batch fans out over (requests are independent
    /// given a frozen snapshot).
    pub workers: usize,
    /// Per-request fold-in protocol. `n_workers` is forced to 1 at
    /// execution time — parallelism lives across requests, so each
    /// request stays bit-deterministic in `(snapshot, doc, seed)`.
    pub fold_in: FoldInConfig,
}

impl Default for ServeConfig {
    /// Paper-shaped serving defaults: scheduled fold-in (10 topics + 2
    /// exploration slots per doc per sweep, per-doc convergence cutoff),
    /// modest batches, one worker.
    fn default() -> Self {
        Self {
            max_batch_docs: 32,
            queue_docs: 256,
            workers: 1,
            fold_in: FoldInConfig::scheduled(10, 30),
        }
    }
}

impl ServeConfig {
    /// Clamp degenerate values (zero sizes) to their minimum of 1.
    pub(crate) fn normalized(mut self) -> Self {
        self.max_batch_docs = self.max_batch_docs.max(1);
        self.queue_docs = self.queue_docs.max(1);
        self.workers = self.workers.max(1);
        self
    }
}
