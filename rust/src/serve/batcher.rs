//! The request batcher: coalesces incoming documents into minibatches
//! and runs them through the fold-in inference engine on a persistent
//! dispatcher with a pooled worker fan-out.
//!
//! **Flow.** [`Server::submit`] enqueues a request on a *bounded* queue
//! (`ServeConfig::queue_docs`) — a full queue blocks the submitter
//! (backpressure), and [`Server::try_submit`] instead fails fast and
//! counts the rejection. The dispatcher thread drains up to
//! `max_batch_docs` pending requests into one batch, resolves each
//! request's snapshot (its pinned epoch, else the registry's current
//! one), and fans the batch out over
//! [`crate::exec::ParallelExecutor::run_ranged`] — each worker folds its
//! request range in through [`crate::em::infer`], whose buffers come
//! from the grow-only [`crate::exec::scratch`] pool, so a steady-state
//! serving loop allocates almost nothing per request beyond its reply.
//!
//! **Determinism.** Every request is folded in with `n_workers = 1` and
//! its own seed — batch composition and pool size parallelize *across*
//! requests, never inside one — so a request's `(theta, perplexity)` is
//! a pure function of `(snapshot, doc, seed, fold_in config)`:
//! bit-identical to an offline [`crate::em::infer::fold_in`] +
//! [`crate::eval::log_likelihood`] run against the same snapshot, no
//! matter what else is in flight (`tests/serve_equivalence.rs`).
//!
//! **Distributed snapshots.** Under a vocabulary-sharded trainer
//! ([`crate::shard`]) the snapshot a request pins is assembled by the
//! scatter-gather router: per-shard view parts, gathered while every
//! shard is quiesced at the same batch cursor, merged into one
//! [`crate::em::EvalPhiView`] before publication
//! ([`ModelRegistry::publish_distributed`]). The batcher is oblivious —
//! a merged snapshot is bit-identical to a single-store one, so the
//! determinism contract above holds unchanged for sharded runs
//! (`tests/shard_equivalence.rs`).

use super::registry::{ModelRegistry, ModelSnapshot};
use super::ServeConfig;
use crate::corpus::sparse::DocWordMatrix;
use crate::em::infer;
use crate::em::PhiAccess;
use crate::exec::ParallelExecutor;
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One served inference result.
#[derive(Debug, Clone)]
pub struct InferResponse {
    /// Epoch of the snapshot the request was evaluated against.
    pub epoch: u64,
    /// Unnormalized document-topic statistics `theta_hat_d` (length K);
    /// normalize with `(theta + alpha-1) / (sum + K(alpha-1))` (Eq. 9).
    pub theta: Vec<f32>,
    /// Perplexity of the request's own tokens under the inferred mixture
    /// (lower = better explained by the pinned model).
    pub perplexity: f64,
    /// Fold-in sweeps actually run (per-doc convergence may stop early).
    pub sweeps: usize,
    /// Submit-to-completion latency, queueing included.
    pub latency: Duration,
}

/// Reply channel alias (a request's one-shot response slot).
type Reply = mpsc::Sender<Result<InferResponse, String>>;

/// What the workers see: the request minus its reply channel (the reply
/// stays on the dispatcher thread; `mpsc::Sender` need not be `Sync`).
struct Payload {
    doc: Vec<(u32, f32)>,
    seed: u64,
    pin: Option<Arc<ModelSnapshot>>,
    submitted: Instant,
}

struct Job {
    payload: Payload,
    reply: Reply,
}

/// Handle to an in-flight request.
pub struct PendingResponse {
    rx: mpsc::Receiver<Result<InferResponse, String>>,
}

impl PendingResponse {
    /// Block until the response arrives.
    pub fn wait(self) -> anyhow::Result<InferResponse> {
        match self.rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(e)) => Err(anyhow::anyhow!(e)),
            Err(_) => Err(anyhow::anyhow!(
                "serve: server shut down before responding"
            )),
        }
    }
}

/// Aggregate serving telemetry, collected by the dispatcher.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    /// Requests answered successfully.
    pub docs: u64,
    /// Requests answered with an error (no snapshot, bad vocabulary).
    pub failed: u64,
    /// Requests refused by [`Server::try_submit`] backpressure.
    pub rejected: u64,
    /// Batches executed.
    pub batches: u64,
    /// Token mass served.
    pub tokens: f64,
    /// Mean coalesced batch size in requests.
    pub mean_batch_docs: f64,
    /// Successful requests per wall-clock second (server start to last
    /// completion).
    pub docs_per_sec: f64,
    /// Median submit-to-completion latency, microseconds.
    pub p50_latency_us: f64,
    /// 99th-percentile submit-to-completion latency, microseconds.
    pub p99_latency_us: f64,
    /// Distribution shifts the trainer's drift monitor has flagged on
    /// this registry ([`crate::coordinator::drift`]); 0 when the
    /// detector is off.
    pub shifts_detected: u64,
    /// Training batch index of the most recent flagged shift.
    pub last_shift_batch: Option<u64>,
}

/// Cap on retained latency samples: a long-running server keeps a
/// sliding window (overwrite ring) instead of unbounded history, so
/// memory stays fixed and [`Server::report`]'s sort stays O(cap log cap)
/// no matter how much traffic has been served.
const LATENCY_SAMPLE_CAP: usize = 1 << 16;

#[derive(Debug, Default)]
struct MetricsInner {
    docs: u64,
    failed: u64,
    rejected: u64,
    batches: u64,
    tokens: f64,
    /// Sliding window of per-request latencies (ring once full).
    latencies_ns: Vec<u64>,
    /// Total latency samples ever taken (ring write cursor).
    samples: u64,
    window: Duration,
}

/// Shared metrics sink (dispatcher writes, [`Server::report`] reads).
#[derive(Debug)]
struct ServeMetrics {
    started: Instant,
    inner: Mutex<MetricsInner>,
}

impl ServeMetrics {
    fn start() -> Self {
        Self { started: Instant::now(), inner: Mutex::default() }
    }

    fn note_rejected(&self) {
        self.inner.lock().expect("metrics lock").rejected += 1;
    }

    fn note_request(&self, ok: bool, tokens: f64, latency: Duration) {
        let mut g = self.inner.lock().expect("metrics lock");
        if ok {
            g.docs += 1;
            g.tokens += tokens;
        } else {
            g.failed += 1;
        }
        let sample = latency.as_nanos() as u64;
        if g.latencies_ns.len() < LATENCY_SAMPLE_CAP {
            g.latencies_ns.push(sample);
        } else {
            let at = (g.samples % LATENCY_SAMPLE_CAP as u64) as usize;
            g.latencies_ns[at] = sample;
        }
        g.samples += 1;
        g.window = self.started.elapsed();
    }

    fn note_batch(&self) {
        self.inner.lock().expect("metrics lock").batches += 1;
    }

    fn report(&self) -> ServeReport {
        let g = self.inner.lock().expect("metrics lock");
        let mut lat = g.latencies_ns.clone();
        lat.sort_unstable();
        let pct = |q: f64| -> f64 {
            if lat.is_empty() {
                return 0.0;
            }
            lat[((lat.len() - 1) as f64 * q) as usize] as f64 / 1e3
        };
        let secs = g.window.as_secs_f64();
        ServeReport {
            docs: g.docs,
            failed: g.failed,
            rejected: g.rejected,
            batches: g.batches,
            tokens: g.tokens,
            mean_batch_docs: if g.batches > 0 {
                (g.docs + g.failed) as f64 / g.batches as f64
            } else {
                0.0
            },
            docs_per_sec: if secs > 0.0 { g.docs as f64 / secs } else { 0.0 },
            p50_latency_us: pct(0.5),
            p99_latency_us: pct(0.99),
            // Filled in by Server::report from the registry's drift
            // telemetry; the raw metrics layer never sees shifts.
            shifts_detected: 0,
            last_shift_batch: None,
        }
    }
}

/// The serving front end: owns the bounded request queue and the
/// dispatcher thread. See the module docs for the batching and
/// determinism contract.
pub struct Server {
    tx: Option<SyncSender<Job>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<ServeMetrics>,
    registry: Arc<ModelRegistry>,
    queue_docs: usize,
}

impl Server {
    /// Start the dispatcher over `registry` with the given policy.
    pub fn start(registry: Arc<ModelRegistry>, cfg: ServeConfig) -> Self {
        let cfg = cfg.normalized();
        let queue_docs = cfg.queue_docs;
        let (tx, rx) = mpsc::sync_channel::<Job>(queue_docs);
        let metrics = Arc::new(ServeMetrics::start());
        let worker_metrics = Arc::clone(&metrics);
        let loop_registry = Arc::clone(&registry);
        let dispatcher = std::thread::Builder::new()
            .name("foem-serve-dispatch".into())
            .spawn(move || {
                dispatch_loop(rx, loop_registry, cfg, worker_metrics)
            })
            .expect("spawn serve dispatcher");
        Self {
            tx: Some(tx),
            dispatcher: Some(dispatcher),
            metrics,
            registry,
            queue_docs,
        }
    }

    /// Submit a document (sparse `(word_id, count)` pairs, counts > 0)
    /// for inference against the *current* epoch at execution time.
    /// Blocks while the queue is full — the backpressure path.
    pub fn submit(
        &self,
        doc: Vec<(u32, f32)>,
        seed: u64,
    ) -> anyhow::Result<PendingResponse> {
        self.enqueue(doc, seed, None, true)
    }

    /// Submit pinned to `snapshot`: the request evaluates against that
    /// epoch even if the trainer publishes newer ones meanwhile.
    pub fn submit_pinned(
        &self,
        doc: Vec<(u32, f32)>,
        seed: u64,
        snapshot: Arc<ModelSnapshot>,
    ) -> anyhow::Result<PendingResponse> {
        self.enqueue(doc, seed, Some(snapshot), true)
    }

    /// Non-blocking [`Server::submit`]: errors immediately when the
    /// queue is full (counted in [`ServeReport::rejected`]) instead of
    /// applying backpressure to the caller.
    pub fn try_submit(
        &self,
        doc: Vec<(u32, f32)>,
        seed: u64,
    ) -> anyhow::Result<PendingResponse> {
        self.enqueue(doc, seed, None, false)
    }

    fn enqueue(
        &self,
        doc: Vec<(u32, f32)>,
        seed: u64,
        pin: Option<Arc<ModelSnapshot>>,
        block: bool,
    ) -> anyhow::Result<PendingResponse> {
        let (reply, rx) = mpsc::channel();
        let job = Job {
            payload: Payload { doc, seed, pin, submitted: Instant::now() },
            reply,
        };
        let tx = self.tx.as_ref().expect("server already shut down");
        if block {
            tx.send(job)
                .map_err(|_| anyhow::anyhow!("serve: dispatcher stopped"))?;
        } else {
            match tx.try_send(job) {
                Ok(()) => {}
                Err(TrySendError::Full(_)) => {
                    self.metrics.note_rejected();
                    anyhow::bail!(
                        "serve: request queue full ({} docs)",
                        self.queue_docs
                    );
                }
                Err(TrySendError::Disconnected(_)) => {
                    anyhow::bail!("serve: dispatcher stopped")
                }
            }
        }
        Ok(PendingResponse { rx })
    }

    /// Current serving telemetry, including the registry's drift
    /// telemetry (shifts the trainer's monitor has flagged so far).
    pub fn report(&self) -> ServeReport {
        let mut report = self.metrics.report();
        let (shifts, last) = self.registry.shift_telemetry();
        report.shifts_detected = shifts;
        report.last_shift_batch = last.map(|e| e.batch as u64);
        report
    }

    /// Stop accepting requests, drain the queue, join the dispatcher and
    /// return the final telemetry.
    pub fn shutdown(mut self) -> ServeReport {
        self.stop();
        self.report()
    }

    fn stop(&mut self) {
        self.tx.take();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

struct RunOut {
    epoch: u64,
    theta: Vec<f32>,
    perplexity: f64,
    sweeps: usize,
    tokens: f64,
}

/// Fold one request in against `snap` — exactly the float ops of an
/// offline `em::infer::fold_in` + `eval::log_likelihood` run (the
/// equivalence contract; see the module docs).
fn run_one(
    snap: &ModelSnapshot,
    payload: &Payload,
    fold_in: &infer::FoldInConfig,
) -> Result<RunOut, String> {
    for &(w, c) in &payload.doc {
        if w as usize >= snap.n_words() {
            return Err(format!(
                "word id {w} outside the snapshot vocabulary ({} words)",
                snap.n_words()
            ));
        }
        if !snap.view().has_word(w) {
            return Err(format!(
                "word id {w} not materialized in the published snapshot"
            ));
        }
        if !c.is_finite() || c <= 0.0 {
            return Err(format!("word {w} has non-positive count {c}"));
        }
    }
    let rows: [&[(u32, f32)]; 1] = [&payload.doc];
    let docs = DocWordMatrix::from_rows(snap.n_words(), &rows);
    let mut cfg = *fold_in;
    // Per-request determinism: the pool parallelizes across requests,
    // never inside one.
    cfg.n_workers = 1;
    let (theta, rep) = infer::fold_in_with_report(
        snap.view(),
        snap.params(),
        &docs,
        &cfg,
        payload.seed,
    );
    let (ll, n) =
        crate::eval::log_likelihood(snap.view(), snap.params(), &theta, &docs);
    Ok(RunOut {
        epoch: snap.epoch(),
        theta: theta.doc(0).to_vec(),
        perplexity: crate::em::perplexity(ll, n),
        sweeps: rep.sweeps,
        tokens: n,
    })
}

/// Minimum requests per worker range before the dispatcher fans a batch
/// out to scoped threads. `run_ranged` runs a single range inline on the
/// dispatcher thread, so batches up to this size pay zero thread
/// spawn/join cost — under light traffic the spawn overhead would
/// otherwise be a real fraction of p50 latency. (Long-lived pool
/// workers would remove the spawn cost at every batch size; that swap
/// stays behind this function's seam.)
const MIN_DOCS_PER_WORKER: usize = 4;

fn dispatch_loop(
    rx: Receiver<Job>,
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
    metrics: Arc<ServeMetrics>,
) {
    while let Ok(first) = rx.recv() {
        // Coalesce whatever else is already queued, up to the batch cap.
        let mut jobs = vec![first];
        while jobs.len() < cfg.max_batch_docs {
            match rx.try_recv() {
                Ok(job) => jobs.push(job),
                Err(_) => break,
            }
        }
        // One snapshot resolution per batch for the unpinned requests:
        // every request of a batch that asked for "latest" sees the same
        // epoch.
        let latest = registry.latest();
        let (payloads, replies): (Vec<Payload>, Vec<Reply>) =
            jobs.into_iter().map(|j| (j.payload, j.reply)).unzip();
        let fan_out = cfg
            .workers
            .min(payloads.len().div_ceil(MIN_DOCS_PER_WORKER));
        let exec = ParallelExecutor::new(fan_out);
        let outs = exec.run_ranged(payloads.len(), |_, range| {
            range
                .map(|i| {
                    let p = &payloads[i];
                    match p.pin.as_deref().or(latest.as_deref()) {
                        None => Err(
                            "no model snapshot published yet".to_string()
                        ),
                        Some(snap) => run_one(snap, p, &cfg.fold_in),
                    }
                })
                .collect::<Vec<_>>()
        });
        metrics.note_batch();
        let results: Vec<Result<RunOut, String>> =
            outs.into_iter().flatten().collect();
        debug_assert_eq!(results.len(), payloads.len());
        for ((payload, reply), result) in
            payloads.iter().zip(replies).zip(results)
        {
            let latency = payload.submitted.elapsed();
            let response = result.map(|out| {
                metrics.note_request(true, out.tokens, latency);
                InferResponse {
                    epoch: out.epoch,
                    theta: out.theta,
                    perplexity: out.perplexity,
                    sweeps: out.sweeps,
                    latency,
                }
            });
            if response.is_err() {
                metrics.note_request(false, 0.0, latency);
            }
            // A dropped receiver just means the client went away.
            let _ = reply.send(response);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::em::{EvalPhiView, PhiStats};
    use crate::LdaParams;

    fn registry_with_model(
        k: usize,
        w: usize,
    ) -> (Arc<ModelRegistry>, LdaParams) {
        let p = LdaParams::paper_defaults(k);
        let mut rng = crate::util::Rng::new(3);
        let mut phi = PhiStats::zeros(k, w);
        let mut col = vec![0.0f32; k];
        for word in 0..w {
            for x in col.iter_mut() {
                *x = rng.next_f32() * 2.0 + 0.05;
            }
            phi.add_to_word(word, &col);
        }
        let words: Vec<u32> = (0..w as u32).collect();
        let reg = Arc::new(ModelRegistry::new());
        reg.publish(EvalPhiView::from_dense(&phi, &words), p);
        (reg, p)
    }

    #[test]
    fn serves_a_batch_of_requests() {
        let (reg, p) = registry_with_model(8, 32);
        let server = Server::start(Arc::clone(&reg), ServeConfig::default());
        let pend: Vec<_> = (0..10)
            .map(|i| {
                let doc = vec![(i as u32, 2.0), (i as u32 + 8, 1.0)];
                server.submit(doc, i as u64).unwrap()
            })
            .collect();
        for pr in pend {
            let resp = pr.wait().unwrap();
            assert_eq!(resp.epoch, 1);
            assert_eq!(resp.theta.len(), p.n_topics);
            let mass: f32 = resp.theta.iter().sum();
            assert!((mass - 3.0).abs() < 1e-2, "theta mass {mass}");
            assert!(resp.perplexity.is_finite() && resp.perplexity > 1.0);
            assert!(resp.sweeps >= 1);
        }
        let report = server.shutdown();
        assert_eq!(report.docs, 10);
        assert_eq!(report.failed, 0);
        assert!(report.batches >= 1);
        assert!(report.docs_per_sec > 0.0);
        assert!(report.p99_latency_us >= report.p50_latency_us);
    }

    #[test]
    fn empty_registry_and_bad_words_fail_cleanly() {
        let reg = Arc::new(ModelRegistry::new());
        let server = Server::start(Arc::clone(&reg), ServeConfig::default());
        let err = server
            .submit(vec![(0, 1.0)], 1)
            .unwrap()
            .wait()
            .expect_err("no snapshot published");
        assert!(err.to_string().contains("no model snapshot"), "{err}");
        // Publish, then request a word outside the vocabulary.
        let (reg2, _) = registry_with_model(4, 8);
        let server2 = Server::start(reg2, ServeConfig::default());
        let err = server2
            .submit(vec![(99, 1.0)], 1)
            .unwrap()
            .wait()
            .expect_err("out-of-vocabulary word");
        assert!(err.to_string().contains("vocabulary"), "{err}");
        let report = server2.shutdown();
        assert_eq!(report.failed, 1);
        assert_eq!(report.docs, 0);
    }

    #[test]
    fn report_surfaces_registry_shift_telemetry() {
        use crate::coordinator::drift::{ShiftDirection, ShiftEvent};
        let (reg, _) = registry_with_model(4, 8);
        let server = Server::start(Arc::clone(&reg), ServeConfig::default());
        let clean = server.report();
        assert_eq!(clean.shifts_detected, 0);
        assert_eq!(clean.last_shift_batch, None);
        // The trainer flags shifts on the shared registry; the serve
        // report picks them up without any request traffic.
        reg.note_shift(ShiftEvent {
            batch: 12,
            direction: ShiftDirection::Down,
            score: 9.0,
        });
        reg.note_shift(ShiftEvent {
            batch: 30,
            direction: ShiftDirection::Up,
            score: 8.2,
        });
        let report = server.shutdown();
        assert_eq!(report.shifts_detected, 2);
        assert_eq!(report.last_shift_batch, Some(30));
    }

    #[test]
    fn unpinned_requests_follow_the_latest_epoch() {
        let (reg, p) = registry_with_model(4, 8);
        let server = Server::start(Arc::clone(&reg), ServeConfig::default());
        let r1 = server.submit(vec![(0, 1.0)], 1).unwrap().wait().unwrap();
        assert_eq!(r1.epoch, 1);
        // Re-publish; the same submission now evaluates against epoch 2.
        let snap = reg.latest().unwrap();
        reg.publish(
            EvalPhiView::from_dense(
                &{
                    let mut phi = PhiStats::zeros(4, 8);
                    for w in 0..8 {
                        phi.add_to_word(w, &[1.0, 2.0, 3.0, 4.0]);
                    }
                    phi
                },
                &(0..8u32).collect::<Vec<_>>(),
            ),
            p,
        );
        let r2 = server.submit(vec![(0, 1.0)], 1).unwrap().wait().unwrap();
        assert_eq!(r2.epoch, 2);
        // The pinned epoch is still available for pinned submissions.
        let r3 = server
            .submit_pinned(vec![(0, 1.0)], 1, snap)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r3.epoch, 1);
        drop(server);
    }
}
