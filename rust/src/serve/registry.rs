//! Epoch-tagged model snapshots and the registry the trainer publishes
//! them through.
//!
//! The registry is the *only* shared state between the training loop and
//! the serving path, and it is deliberately tiny: an atomic swap of an
//! `Arc<ModelSnapshot>`. Publishing is one column-snapshot read of the
//! store (`PhiColumnStore::snapshot_columns` via `OnlineLda::eval_view`)
//! plus an `Arc` allocation; readers never block the trainer and the
//! trainer never blocks readers. Retirement is reference counting: when a
//! new epoch is published the registry drops its strong reference to the
//! old one, so an old epoch lives exactly as long as its last pinned
//! reader and is freed the moment that reader drops — no epoch GC, no
//! generation list to compact.

use crate::coordinator::drift::ShiftEvent;
use crate::em::{EvalPhiView, PhiAccess};
use crate::LdaParams;
use std::sync::{Arc, Mutex, Weak};

/// One immutable, epoch-tagged publication of the model: the topic-word
/// view the snapshot was taken over plus the smoothing parameters the
/// evaluator must use with it ([`crate::baselines::OnlineLda::eval_params`]).
///
/// A snapshot is the unit requests pin to: everything a fold-in needs is
/// frozen inside it, so a request evaluated against epoch `E` is
/// bit-identical to an offline [`crate::em::infer::fold_in`] run against
/// this snapshot's view, no matter how many newer epochs the trainer has
/// published meanwhile (`tests/serve_equivalence.rs`).
#[derive(Debug)]
pub struct ModelSnapshot {
    epoch: u64,
    params: LdaParams,
    view: EvalPhiView,
}

impl ModelSnapshot {
    /// The publication epoch (1-based; assigned by the registry).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The smoothing parameterization matching how the view was produced.
    pub fn params(&self) -> &LdaParams {
        &self.params
    }

    /// The frozen topic-word view requests are folded in against.
    pub fn view(&self) -> &EvalPhiView {
        &self.view
    }

    /// How many of this snapshot's materialized columns the store's
    /// zone maps certified as all-zero at publish time (see
    /// [`EvalPhiView::known_cold_columns`]) — an observability hook for
    /// sizing request vocabularies against actually-trained mass.
    pub fn known_cold_columns(&self) -> usize {
        self.view.known_cold_columns()
    }
}

impl PhiAccess for ModelSnapshot {
    fn k(&self) -> usize {
        self.view.k()
    }

    fn n_words(&self) -> usize {
        self.view.n_words()
    }

    fn phisum(&self) -> &[f32] {
        self.view.phisum()
    }

    fn word(&self, w: usize) -> &[f32] {
        self.view.word(w)
    }
}

#[derive(Debug, Default)]
struct Inner {
    current: Option<Arc<ModelSnapshot>>,
    last_epoch: u64,
    /// Weak handles to every epoch ever published and not yet dropped —
    /// observability only (never keeps an epoch alive).
    history: Vec<(u64, Weak<ModelSnapshot>)>,
    /// Drift telemetry the trainer pushes alongside publishes: how many
    /// distribution shifts its monitor has flagged, and the most recent
    /// one ([`crate::coordinator::drift::DriftMonitor`]).
    shifts_detected: u64,
    last_shift: Option<ShiftEvent>,
}

/// The publish/subscribe point between one trainer and any number of
/// serving readers.
///
/// The trainer calls [`ModelRegistry::publish`] with a fresh eval view;
/// readers call [`ModelRegistry::latest`] to pin the current epoch. Both
/// are a mutex-guarded pointer swap/clone — the lock is held for O(1),
/// never across I/O or compute.
#[derive(Debug, Default)]
pub struct ModelRegistry {
    inner: Mutex<Inner>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish `view` as the next epoch and make it current. Returns the
    /// new snapshot (the trainer may keep or drop it; the registry holds
    /// its own reference until the next publish).
    pub fn publish(
        &self,
        view: EvalPhiView,
        params: LdaParams,
    ) -> Arc<ModelSnapshot> {
        let mut g = self.inner.lock().expect("registry lock");
        g.last_epoch += 1;
        let snap =
            Arc::new(ModelSnapshot { epoch: g.last_epoch, params, view });
        g.history.retain(|(_, w)| w.strong_count() > 0);
        g.history.push((g.last_epoch, Arc::downgrade(&snap)));
        g.current = Some(Arc::clone(&snap));
        snap
    }

    /// Publish one epoch assembled from per-shard view parts — the
    /// gather half of the vocabulary-sharded serve router
    /// ([`crate::shard`]).
    ///
    /// The distributed-snapshot protocol is enforced upstream by
    /// construction: the coordinator collects the parts over the
    /// fleet's synchronous request/response transport between
    /// minibatches, so every shard is quiesced at the SAME batch
    /// cursor when its part is read — there is no torn epoch to
    /// detect. `parts` must arrive in ascending shard order (as
    /// returned by `Foem::shard_eval_views`); the merged view is then
    /// bit-identical to a single-store `eval_view` over the same
    /// words, and every fold-in against the published snapshot is
    /// bit-identical to the unsharded serve path
    /// (`tests/shard_equivalence.rs`).
    pub fn publish_distributed(
        &self,
        parts: Vec<EvalPhiView>,
        params: LdaParams,
    ) -> Arc<ModelSnapshot> {
        self.publish(EvalPhiView::merge_shards(parts), params)
    }

    /// [`Self::restore_epoch_floor`] for a resumed sharded run: each
    /// shard recovers its own epoch floor from its checkpoint, and the
    /// registry must not regress below ANY of them — max semantics
    /// across the fleet, then max against the registry's own state.
    pub fn restore_epoch_floor_distributed(
        &self,
        floors: impl IntoIterator<Item = u64>,
    ) {
        if let Some(max) = floors.into_iter().max() {
            self.restore_epoch_floor(max);
        }
    }

    /// Pin the current epoch (`None` until the first publish). The
    /// returned `Arc` keeps that epoch alive for as long as the caller
    /// holds it, regardless of later publishes.
    pub fn latest(&self) -> Option<Arc<ModelSnapshot>> {
        self.inner.lock().expect("registry lock").current.clone()
    }

    /// Epoch of the most recent publish (0 = nothing published yet).
    pub fn current_epoch(&self) -> u64 {
        self.inner.lock().expect("registry lock").last_epoch
    }

    /// Raise the epoch counter to at least `epoch` without publishing.
    ///
    /// Crash recovery calls this with the epoch recorded in the trainer
    /// checkpoint before the resumed run's first publish, so consumers
    /// that survived the trainer restart (or compare epochs across it)
    /// never observe a pre-crash epoch regression. Max semantics: a
    /// registry that has already moved past `epoch` is left alone.
    pub fn restore_epoch_floor(&self, epoch: u64) {
        let mut g = self.inner.lock().expect("registry lock");
        g.last_epoch = g.last_epoch.max(epoch);
    }

    /// Record one detected distribution shift from the trainer's drift
    /// monitor. Readers pick it up via [`Self::shift_telemetry`]; the
    /// serve report surfaces it as `shifts_detected` /
    /// `last_shift_batch` ([`crate::serve::ServeReport`]).
    pub fn note_shift(&self, event: ShiftEvent) {
        let mut g = self.inner.lock().expect("registry lock");
        g.shifts_detected += 1;
        g.last_shift = Some(event);
    }

    /// Drift telemetry: (total shifts noted, most recent event). Both
    /// are zero/`None` until the trainer's monitor first fires.
    pub fn shift_telemetry(&self) -> (u64, Option<ShiftEvent>) {
        let g = self.inner.lock().expect("registry lock");
        (g.shifts_detected, g.last_shift)
    }

    /// Epochs still alive (current + any older epoch a reader still
    /// pins), ascending. Old epochs disappear from this list as soon as
    /// their last reader drops — the retirement contract, observable.
    pub fn live_epochs(&self) -> Vec<u64> {
        let mut g = self.inner.lock().expect("registry lock");
        g.history.retain(|(_, w)| w.strong_count() > 0);
        g.history.iter().map(|(e, _)| *e).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::em::PhiStats;

    fn view(k: usize, w: usize, fill: f32) -> EvalPhiView {
        let mut phi = PhiStats::zeros(k, w);
        for word in 0..w {
            phi.add_to_word(word, &vec![fill; k]);
        }
        let words: Vec<u32> = (0..w as u32).collect();
        EvalPhiView::from_dense(&phi, &words)
    }

    #[test]
    fn publish_bumps_epoch_and_swaps_current() {
        let p = LdaParams::paper_defaults(3);
        let reg = ModelRegistry::new();
        assert!(reg.latest().is_none());
        assert_eq!(reg.current_epoch(), 0);
        let a = reg.publish(view(3, 4, 1.0), p);
        assert_eq!(a.epoch(), 1);
        let b = reg.publish(view(3, 4, 2.0), p);
        assert_eq!(b.epoch(), 2);
        let latest = reg.latest().unwrap();
        assert_eq!(latest.epoch(), 2);
        assert_eq!(latest.word(0)[0], 2.0);
        assert_eq!(reg.current_epoch(), 2);
    }

    #[test]
    fn old_epoch_retires_when_last_reader_drops() {
        let p = LdaParams::paper_defaults(2);
        let reg = ModelRegistry::new();
        reg.publish(view(2, 2, 1.0), p);
        let pinned = reg.latest().unwrap();
        reg.publish(view(2, 2, 2.0), p);
        // Epoch 1 is still alive: `pinned` holds it.
        assert_eq!(reg.live_epochs(), vec![1, 2]);
        assert_eq!(pinned.word(1)[0], 1.0);
        drop(pinned);
        // ... and retires the moment its last reader is gone.
        assert_eq!(reg.live_epochs(), vec![2]);
    }

    #[test]
    fn recovery_epoch_floor_prevents_regression() {
        let p = LdaParams::paper_defaults(2);
        let reg = ModelRegistry::new();
        // Fresh registry after a trainer restart: the checkpoint said the
        // pre-crash run had already published epoch 7.
        reg.restore_epoch_floor(7);
        assert_eq!(reg.current_epoch(), 7);
        assert!(reg.latest().is_none(), "floor restore publishes nothing");
        let snap = reg.publish(view(2, 2, 1.0), p);
        assert_eq!(snap.epoch(), 8, "first post-recovery publish moves on");
        // Max semantics: a stale floor never rolls an advanced registry back.
        reg.restore_epoch_floor(3);
        assert_eq!(reg.current_epoch(), 8);
    }

    #[test]
    fn shard_distributed_publish_matches_single_view() {
        let p = LdaParams::paper_defaults(2);
        let mut phi = PhiStats::zeros(2, 4);
        for w in 0..4 {
            phi.add_to_word(w, &[w as f32 + 1.0, 0.5]);
        }
        let full = EvalPhiView::from_dense(&phi, &[0, 1, 2, 3]);
        // Per-shard parts in ascending shard order, sharing the
        // trainer's resident phisum — exactly what the scatter half
        // hands the registry.
        let parts = vec![
            EvalPhiView::from_dense(&phi, &[0, 1]),
            EvalPhiView::from_dense(&phi, &[2, 3]),
        ];
        let reg = ModelRegistry::new();
        let snap = reg.publish_distributed(parts, p);
        assert_eq!(snap.epoch(), 1);
        assert_eq!(snap.n_words(), full.n_words());
        assert_eq!(snap.phisum(), full.phisum());
        for w in 0..4 {
            assert_eq!(snap.word(w), full.word(w), "column {w} diverged");
        }
    }

    #[test]
    fn shard_distributed_epoch_floor_takes_fleet_max() {
        let reg = ModelRegistry::new();
        reg.restore_epoch_floor_distributed([3u64, 7, 5]);
        assert_eq!(reg.current_epoch(), 7);
        // An empty fleet (or stale floors) never regresses the registry.
        reg.restore_epoch_floor_distributed(std::iter::empty::<u64>());
        reg.restore_epoch_floor_distributed([2u64]);
        assert_eq!(reg.current_epoch(), 7);
    }

    #[test]
    fn snapshot_is_immutable_across_publishes() {
        let p = LdaParams::paper_defaults(2);
        let reg = ModelRegistry::new();
        let a = reg.publish(view(2, 3, 5.0), p);
        reg.publish(view(2, 3, 9.0), p);
        assert_eq!(a.word(2), &[5.0, 5.0]);
        assert_eq!(a.phisum(), &[15.0, 15.0]);
    }

    #[test]
    fn shift_telemetry_counts_and_keeps_latest() {
        use crate::coordinator::drift::ShiftDirection;
        let reg = ModelRegistry::new();
        assert_eq!(reg.shift_telemetry(), (0, None));
        let a = ShiftEvent {
            batch: 7,
            direction: ShiftDirection::Down,
            score: 9.5,
        };
        let b = ShiftEvent {
            batch: 21,
            direction: ShiftDirection::Up,
            score: 8.1,
        };
        reg.note_shift(a);
        reg.note_shift(b);
        let (n, last) = reg.shift_telemetry();
        assert_eq!(n, 2);
        assert_eq!(last, Some(b));
    }

    #[test]
    fn concurrent_readers_see_monotone_epochs() {
        let p = LdaParams::paper_defaults(2);
        let reg = ModelRegistry::new();
        reg.publish(view(2, 2, 1.0), p);
        std::thread::scope(|s| {
            let publisher = s.spawn(|| {
                for i in 0..50 {
                    reg.publish(view(2, 2, i as f32), p);
                }
            });
            for _ in 0..4 {
                s.spawn(|| {
                    let mut last = 0u64;
                    for _ in 0..200 {
                        let e = reg.latest().unwrap().epoch();
                        assert!(e >= last, "epoch went backwards");
                        last = e;
                    }
                });
            }
            publisher.join().unwrap();
        });
        assert_eq!(reg.current_epoch(), 51);
    }
}
