//! Artifact registry: parses `artifacts/manifest.tsv` (one artifact per
//! line, `key=value` pairs) emitted by `python/compile/aot.py` alongside
//! the human-readable `manifest.json`.

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Metadata of one AOT artifact.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// Graph family: "estep" | "predict" | "sem".
    pub graph: String,
    /// Entry-block size B.
    pub b: usize,
    /// Topic capacity K.
    pub k: usize,
    /// SEM only: local doc capacity.
    pub ds: usize,
    /// SEM only: local vocab capacity.
    pub ws: usize,
    /// SEM only: inner sweeps baked into the graph.
    pub iters: usize,
}

/// The set of artifacts available in a directory.
#[derive(Debug, Clone)]
pub struct Registry {
    dir: PathBuf,
    artifacts: Vec<ArtifactMeta>,
}

impl Registry {
    /// Parse `<dir>/manifest.tsv`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("{path:?} missing — run `make artifacts` first")
        })?;
        let mut artifacts = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut meta = ArtifactMeta::default();
            for kv in line.split_ascii_whitespace() {
                let (key, value) = kv
                    .split_once('=')
                    .with_context(|| format!("line {}: bad pair {kv}", ln + 1))?;
                match key {
                    "name" => meta.name = value.to_string(),
                    "file" => meta.file = value.to_string(),
                    "graph" => meta.graph = value.to_string(),
                    "b" => meta.b = value.parse()?,
                    "k" => meta.k = value.parse()?,
                    "ds" => meta.ds = value.parse()?,
                    "ws" => meta.ws = value.parse()?,
                    "iters" => meta.iters = value.parse()?,
                    _ => {} // forward-compatible
                }
            }
            anyhow::ensure!(!meta.name.is_empty(), "line {}: no name", ln + 1);
            anyhow::ensure!(!meta.file.is_empty(), "line {}: no file", ln + 1);
            artifacts.push(meta);
        }
        Ok(Self { dir: dir.to_path_buf(), artifacts })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.artifacts.iter()
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_lines() {
        let dir = crate::util::TempDir::new("registry");
        std::fs::write(
            dir.path().join("manifest.tsv"),
            "name=estep_b8_k4 file=e.hlo.txt graph=estep b=8 k=4\n\
             # comment\n\
             \n\
             name=sem_x file=s.hlo.txt graph=sem b=16 k=4 ds=2 ws=8 iters=3\n",
        )
        .unwrap();
        let r = Registry::load(dir.path()).unwrap();
        assert_eq!(r.len(), 2);
        let e = r.get("estep_b8_k4").unwrap();
        assert_eq!(e.graph, "estep");
        assert_eq!((e.b, e.k), (8, 4));
        let s = r.get("sem_x").unwrap();
        assert_eq!((s.ds, s.ws, s.iters), (2, 8, 3));
        assert!(r.get("nope").is_none());
    }

    #[test]
    fn missing_manifest_is_a_clear_error() {
        let dir = crate::util::TempDir::new("registry2");
        let err = Registry::load(dir.path()).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn rejects_nameless_lines() {
        let dir = crate::util::TempDir::new("registry3");
        std::fs::write(dir.path().join("manifest.tsv"), "graph=estep b=8\n")
            .unwrap();
        assert!(Registry::load(dir.path()).is_err());
    }
}
