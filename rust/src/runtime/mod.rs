//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` (the L2 JAX graphs wrapping the L1 Pallas
//! kernels) and executes them from the Rust hot path.
//!
//! Wiring (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  HLO *text* is the interchange format —
//! jax ≥ 0.5 serialized protos use 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Python never runs at this point: artifacts are built once by
//! `make artifacts` and the binary is self-contained afterwards.
//!
//! The PJRT execution path needs the `xla` bindings, which are heavy and
//! not part of the default dependency set; it is therefore gated behind
//! the off-by-default `pjrt` cargo feature. Without it, [`Executor`] is a
//! metadata-only stub: the artifact [`registry`] still parses and
//! variant selection still works, but `run_*` returns a clear error
//! telling the caller to rebuild with `--features pjrt`.

pub mod registry;

/// Outputs of the blocked E-step graph.
pub struct EstepOut {
    /// `[B*K]` row-major responsibilities.
    pub mu: Vec<f32>,
    /// `[B*K]` count-weighted responsibilities.
    pub xmu: Vec<f32>,
}

#[cfg(feature = "pjrt")]
mod pjrt {
    use super::registry::{ArtifactMeta, Registry};
    use super::EstepOut;
    use anyhow::{Context, Result};

    /// A compiled, ready-to-execute artifact.
    pub struct LoadedGraph {
        pub meta: ArtifactMeta,
        exe: xla::PjRtLoadedExecutable,
    }

    /// The PJRT executor: owns the CPU client and a cache of compiled
    /// executables, one per artifact.
    pub struct Executor {
        client: xla::PjRtClient,
        registry: Registry,
        cache: std::collections::HashMap<String, LoadedGraph>,
    }

    impl Executor {
        /// Create a CPU executor over an artifact directory (usually
        /// `artifacts/`).
        pub fn new(artifact_dir: &std::path::Path) -> Result<Self> {
            let registry = Registry::load(artifact_dir)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
            Ok(Self { client, registry, cache: std::collections::HashMap::new() })
        }

        pub fn registry(&self) -> &Registry {
            &self.registry
        }

        /// Compile (and cache) an artifact by name.
        pub fn load(&mut self, name: &str) -> Result<&LoadedGraph> {
            if !self.cache.contains_key(name) {
                let meta = self
                    .registry
                    .get(name)
                    .with_context(|| format!("unknown artifact {name}"))?
                    .clone();
                let path = self.registry.dir().join(&meta.file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 path")?,
                )
                .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
                self.cache.insert(name.to_string(), LoadedGraph { meta, exe });
            }
            Ok(&self.cache[name])
        }

        /// Pick the smallest estep variant with `k_cap >= k`; callers pad
        /// the topic axis per the `-(alpha-1)` contract.
        pub fn estep_variant_for(&self, k: usize) -> Option<ArtifactMeta> {
            self.registry
                .iter()
                .filter(|m| m.graph == "estep" && m.k >= k)
                .min_by_key(|m| m.k)
                .cloned()
        }

        /// Execute the blocked E-step graph `name` on row-major inputs.
        ///
        /// `theta`/`phi` are `[B*K]`, `phisum` `[K]`, `counts` `[B]`; the
        /// caller is responsible for padding B and K to the artifact's
        /// shape (see [`Executor::estep_variant_for`]).
        #[allow(clippy::too_many_arguments)]
        pub fn run_estep(
            &mut self,
            name: &str,
            theta: &[f32],
            phi: &[f32],
            phisum: &[f32],
            counts: &[f32],
            am1: f32,
            bm1: f32,
            wbm1: f32,
        ) -> Result<EstepOut> {
            let graph = self.load(name)?;
            let b = graph.meta.b as i64;
            let k = graph.meta.k as i64;
            anyhow::ensure!(theta.len() as i64 == b * k, "theta shape");
            anyhow::ensure!(phisum.len() as i64 == k, "phisum shape");
            anyhow::ensure!(counts.len() as i64 == b, "counts shape");

            let theta_l = xla::Literal::vec1(theta).reshape(&[b, k])?;
            let phi_l = xla::Literal::vec1(phi).reshape(&[b, k])?;
            let phisum_l = xla::Literal::vec1(phisum).reshape(&[1, k])?;
            let counts_l = xla::Literal::vec1(counts).reshape(&[b, 1])?;
            let consts_l = xla::Literal::vec1(&[am1, bm1, wbm1]);

            let result = graph
                .exe
                .execute::<xla::Literal>(&[
                    theta_l, phi_l, phisum_l, counts_l, consts_l,
                ])
                .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
            let (mu_l, xmu_l) = result.to_tuple2()?;
            Ok(EstepOut {
                mu: mu_l.to_vec::<f32>()?,
                xmu: xmu_l.to_vec::<f32>()?,
            })
        }

        /// Execute the held-out log-likelihood graph; returns
        /// `(ll, count)`.
        #[allow(clippy::too_many_arguments)]
        pub fn run_predict(
            &mut self,
            name: &str,
            theta: &[f32],
            theta_tot: &[f32],
            phi: &[f32],
            phisum: &[f32],
            counts: &[f32],
            consts4: [f32; 4],
        ) -> Result<(f32, f32)> {
            let graph = self.load(name)?;
            let b = graph.meta.b as i64;
            let k = graph.meta.k as i64;
            let theta_l = xla::Literal::vec1(theta).reshape(&[b, k])?;
            let tt_l = xla::Literal::vec1(theta_tot).reshape(&[b, 1])?;
            let phi_l = xla::Literal::vec1(phi).reshape(&[b, k])?;
            let phisum_l = xla::Literal::vec1(phisum).reshape(&[1, k])?;
            let counts_l = xla::Literal::vec1(counts).reshape(&[b, 1])?;
            let consts_l = xla::Literal::vec1(&consts4);
            let result = graph
                .exe
                .execute::<xla::Literal>(&[
                    theta_l, tt_l, phi_l, phisum_l, counts_l, consts_l,
                ])
                .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
            let (ll_l, cnt_l) = result.to_tuple2()?;
            Ok((ll_l.to_vec::<f32>()?[0], cnt_l.to_vec::<f32>()?[0]))
        }

        /// Execute the fused SEM minibatch graph.
        #[allow(clippy::too_many_arguments)]
        pub fn run_sem(
            &mut self,
            name: &str,
            doc_ids: &[i32],
            word_ids: &[i32],
            counts: &[f32],
            theta0: &[f32],
            phi_local: &[f32],
            phisum: &[f32],
            consts3: [f32; 3],
        ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
            let graph = self.load(name)?;
            let b = graph.meta.b as i64;
            let k = graph.meta.k as i64;
            let ds = graph.meta.ds as i64;
            let ws = graph.meta.ws as i64;
            let doc_l = xla::Literal::vec1(doc_ids).reshape(&[b, 1])?;
            let word_l = xla::Literal::vec1(word_ids).reshape(&[b, 1])?;
            let counts_l = xla::Literal::vec1(counts).reshape(&[b, 1])?;
            let theta_l = xla::Literal::vec1(theta0).reshape(&[ds, k])?;
            let phi_l = xla::Literal::vec1(phi_local).reshape(&[ws, k])?;
            let phisum_l = xla::Literal::vec1(phisum).reshape(&[1, k])?;
            let consts_l = xla::Literal::vec1(&consts3);
            let result = graph
                .exe
                .execute::<xla::Literal>(&[
                    doc_l, word_l, counts_l, theta_l, phi_l, phisum_l,
                    consts_l,
                ])
                .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
            let (theta_l, delta_l, ll_l) = result.to_tuple3()?;
            Ok((
                theta_l.to_vec::<f32>()?,
                delta_l.to_vec::<f32>()?,
                ll_l.to_vec::<f32>()?[0],
            ))
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt::{Executor, LoadedGraph};

#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::registry::{ArtifactMeta, Registry};
    use super::EstepOut;
    use anyhow::Result;

    const NO_PJRT: &str = "foem was built without the `pjrt` feature; \
         executing AOT artifacts needs the XLA/PJRT bindings — rebuild \
         with `--features pjrt` after vendoring the `xla` crate";

    /// Metadata-only executor compiled when the `pjrt` feature is off:
    /// the artifact registry stays queryable, execution returns a clear
    /// error instead of linking the XLA runtime.
    pub struct Executor {
        registry: Registry,
    }

    impl Executor {
        /// Open the artifact registry in `artifact_dir` (no PJRT client).
        pub fn new(artifact_dir: &std::path::Path) -> Result<Self> {
            Ok(Self { registry: Registry::load(artifact_dir)? })
        }

        pub fn registry(&self) -> &Registry {
            &self.registry
        }

        /// Pick the smallest estep variant with `k_cap >= k` (metadata
        /// query; works without PJRT).
        pub fn estep_variant_for(&self, k: usize) -> Option<ArtifactMeta> {
            self.registry
                .iter()
                .filter(|m| m.graph == "estep" && m.k >= k)
                .min_by_key(|m| m.k)
                .cloned()
        }

        #[allow(clippy::too_many_arguments)]
        pub fn run_estep(
            &mut self,
            _name: &str,
            _theta: &[f32],
            _phi: &[f32],
            _phisum: &[f32],
            _counts: &[f32],
            _am1: f32,
            _bm1: f32,
            _wbm1: f32,
        ) -> Result<EstepOut> {
            anyhow::bail!(NO_PJRT)
        }

        #[allow(clippy::too_many_arguments)]
        pub fn run_predict(
            &mut self,
            _name: &str,
            _theta: &[f32],
            _theta_tot: &[f32],
            _phi: &[f32],
            _phisum: &[f32],
            _counts: &[f32],
            _consts4: [f32; 4],
        ) -> Result<(f32, f32)> {
            anyhow::bail!(NO_PJRT)
        }

        #[allow(clippy::too_many_arguments)]
        pub fn run_sem(
            &mut self,
            _name: &str,
            _doc_ids: &[i32],
            _word_ids: &[i32],
            _counts: &[f32],
            _theta0: &[f32],
            _phi_local: &[f32],
            _phisum: &[f32],
            _consts3: [f32; 3],
        ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
            anyhow::bail!(NO_PJRT)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::Executor;

#[cfg(test)]
mod tests {
    // Executor tests live in rust/tests/runtime_artifacts.rs because they
    // need the artifacts directory built by `make artifacts`.
}
