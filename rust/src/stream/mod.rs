//! Minibatch streaming: frames a corpus (or an endless generator) into the
//! document-major minibatches `x^s_{w,d}` that every online algorithm in
//! the paper consumes (Fig. 3 / Fig. 4 line 1), including the vocab-major
//! reorganization FOEM needs for one-I/O-per-column parameter streaming
//! (§3.2).

use crate::corpus::sparse::{DocWordMatrix, VocabMajorMatrix};
use crate::corpus::Corpus;

/// One minibatch of the stream: the `D_s` documents in both layouts plus
/// the local vocabulary.
#[derive(Debug, Clone)]
pub struct Minibatch {
    /// Minibatch index `s` (1-based like the paper, so ρ_s = 1/s works).
    pub index: usize,
    /// Doc-major local matrix (word ids are *global*).
    pub docs: DocWordMatrix,
    /// Vocab-major reorganization (§3.2: "we reorganize each incoming
    /// minibatch as a vocabulary-major sparse matrix").
    pub vocab_major: VocabMajorMatrix,
    /// Sorted distinct global word ids present (the local vocabulary W_s).
    pub local_words: Vec<u32>,
}

impl Minibatch {
    pub fn new(index: usize, docs: DocWordMatrix) -> Self {
        let vocab_major = docs.to_vocab_major();
        let local_words = docs.distinct_words();
        Self { index, docs, vocab_major, local_words }
    }

    /// Local vocabulary size W_s.
    pub fn n_local_words(&self) -> usize {
        self.local_words.len()
    }

    pub fn n_docs(&self) -> usize {
        self.docs.n_docs
    }

    pub fn nnz(&self) -> usize {
        self.docs.nnz()
    }

    /// Split into at most `p` contiguous document shards for the parallel
    /// E-step engine ([`crate::exec`]). Each shard keeps the vocab-major
    /// layout over its own documents (its own CSC + local vocabulary), so
    /// a shard worker sweeps it exactly like a serial minibatch. Word ids
    /// stay global; `doc_offset` maps shard-local doc ids back to the
    /// minibatch's. Documents are split evenly; with fewer documents than
    /// `p`, fewer (single-document) shards are returned.
    pub fn shard(&self, p: usize) -> Vec<MinibatchShard> {
        let n_docs = self.docs.n_docs;
        let p = p.clamp(1, n_docs.max(1));
        let mut shards = Vec::with_capacity(p);
        let mut start = 0usize;
        for i in 0..p {
            let remaining = p - i;
            let take = (n_docs - start).div_ceil(remaining);
            let end = start + take;
            let docs = self.docs.slice_docs(start, end);
            let vocab_major = docs.to_vocab_major();
            let local_words = docs.distinct_words();
            shards.push(MinibatchShard {
                shard_index: i,
                doc_offset: start,
                docs,
                vocab_major,
                local_words,
            });
            start = end;
            if start >= n_docs {
                break;
            }
        }
        shards
    }
}

/// One document shard of a minibatch — the unit of work of the parallel
/// E-step engine. Structurally a mini-minibatch: doc-major and
/// vocab-major layouts plus the shard's local vocabulary (a subset of the
/// parent minibatch's `local_words`).
#[derive(Debug, Clone)]
pub struct MinibatchShard {
    /// Position in the parent minibatch's shard list (the fixed merge
    /// order of the executor's reduction).
    pub shard_index: usize,
    /// First parent-minibatch document this shard covers; shard-local doc
    /// `d` is parent doc `doc_offset + d`.
    pub doc_offset: usize,
    /// Doc-major rows of this shard (global word ids).
    pub docs: DocWordMatrix,
    /// Vocab-major reorganization of the same rows.
    pub vocab_major: VocabMajorMatrix,
    /// Sorted distinct global word ids present in this shard.
    pub local_words: Vec<u32>,
}

impl MinibatchShard {
    pub fn n_docs(&self) -> usize {
        self.docs.n_docs
    }

    pub fn nnz(&self) -> usize {
        self.docs.nnz()
    }

    pub fn n_local_words(&self) -> usize {
        self.local_words.len()
    }
}

/// Configuration of the stream framing.
#[derive(Debug, Clone, Copy)]
pub struct StreamConfig {
    /// Minibatch size `D_s` in documents (paper default 1024, §4.3).
    pub minibatch_docs: usize,
    /// Shuffle document order before framing (deterministic in `seed`).
    pub shuffle: bool,
    pub seed: u64,
}

impl Default for StreamConfig {
    fn default() -> Self {
        Self { minibatch_docs: 1024, shuffle: false, seed: 0 }
    }
}

/// Iterator of minibatches over a corpus; one pass = one "epoch" of the
/// stream. For lifelong experiments wrap it in [`RepeatingStream`].
pub struct CorpusStream<'a> {
    corpus: &'a Corpus,
    order: Vec<usize>,
    cfg: StreamConfig,
    cursor: usize,
    next_index: usize,
}

impl<'a> CorpusStream<'a> {
    pub fn new(corpus: &'a Corpus, cfg: StreamConfig) -> Self {
        let mut order: Vec<usize> = (0..corpus.n_docs()).collect();
        if cfg.shuffle {
            let mut rng = crate::util::Rng::new(cfg.seed);
            rng.shuffle(&mut order);
        }
        Self { corpus, order, cfg, cursor: 0, next_index: 1 }
    }

    /// Total number of minibatches in one pass (the paper's S for a
    /// finite corpus; the scaling coefficient of Eq. 20 is `S = D / D_s`).
    pub fn batches_per_pass(&self) -> usize {
        self.corpus.n_docs().div_ceil(self.cfg.minibatch_docs)
    }

    /// Restart the pass (lifelong streams loop passes).
    pub fn rewind(&mut self) {
        self.cursor = 0;
    }
}

impl<'a> Iterator for CorpusStream<'a> {
    type Item = Minibatch;

    fn next(&mut self) -> Option<Minibatch> {
        if self.cursor >= self.order.len() {
            return None;
        }
        let end = (self.cursor + self.cfg.minibatch_docs).min(self.order.len());
        let rows: Vec<Vec<(u32, f32)>> = self.order[self.cursor..end]
            .iter()
            .map(|&d| self.corpus.docs.iter_doc(d).collect())
            .collect();
        let refs: Vec<&[(u32, f32)]> =
            rows.iter().map(|r| r.as_slice()).collect();
        let docs = DocWordMatrix::from_rows(self.corpus.n_words(), &refs);
        self.cursor = end;
        let mb = Minibatch::new(self.next_index, docs);
        self.next_index += 1;
        Some(mb)
    }
}

/// Bounded lookahead over a minibatch stream: the framing seam of the
/// software pipeline ([`crate::exec::pipeline`]). `next` yields batches in
/// order while keeping up to `ahead` upcoming batches framed, so the
/// pipeline can [`Lookahead::peek`] at batch `t+1..t+d`'s local
/// vocabularies and hand them to the stores' prefetchers while batch `t`
/// computes.
pub struct Lookahead<I: Iterator<Item = Minibatch>> {
    inner: I,
    buf: std::collections::VecDeque<Minibatch>,
    ahead: usize,
}

impl<I: Iterator<Item = Minibatch>> Lookahead<I> {
    pub fn new(inner: I, ahead: usize) -> Self {
        Self { inner, buf: std::collections::VecDeque::new(), ahead }
    }

    /// The `i`-th upcoming minibatch: after `next` has returned batch
    /// `t`, `peek(0)` is batch `t+1`. Only the `ahead` batches past the
    /// cursor are framed; `i >= ahead` or stream exhaustion yields
    /// `None`.
    pub fn peek(&self, i: usize) -> Option<&Minibatch> {
        self.buf.get(i)
    }

    /// How many upcoming batches are currently framed.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }
}

impl<I: Iterator<Item = Minibatch>> Iterator for Lookahead<I> {
    type Item = Minibatch;

    fn next(&mut self) -> Option<Minibatch> {
        let out = match self.buf.pop_front() {
            Some(mb) => Some(mb),
            None => self.inner.next(),
        };
        while self.buf.len() < self.ahead {
            match self.inner.next() {
                Some(mb) => self.buf.push_back(mb),
                None => break,
            }
        }
        out
    }
}

/// Endless stream: cycles passes over the corpus forever, reshuffling each
/// pass when configured. Minibatch indices keep increasing across passes
/// so learning-rate schedules keep decaying — this is the "lifelong topic
/// modeling" mode of §1.
pub struct RepeatingStream<'a> {
    corpus: &'a Corpus,
    cfg: StreamConfig,
    inner: CorpusStream<'a>,
    pass: usize,
    next_index: usize,
}

impl<'a> RepeatingStream<'a> {
    pub fn new(corpus: &'a Corpus, cfg: StreamConfig) -> Self {
        let inner = CorpusStream::new(corpus, cfg.clone());
        Self { corpus, cfg, inner, pass: 0, next_index: 1 }
    }

    pub fn pass(&self) -> usize {
        self.pass
    }
}

impl<'a> Iterator for RepeatingStream<'a> {
    type Item = Minibatch;

    fn next(&mut self) -> Option<Minibatch> {
        loop {
            if let Some(mut mb) = self.inner.next() {
                mb.index = self.next_index;
                self.next_index += 1;
                return Some(mb);
            }
            self.pass += 1;
            let mut cfg = self.cfg.clone();
            cfg.seed = cfg.seed.wrapping_add(self.pass as u64);
            self.inner = CorpusStream::new(self.corpus, cfg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};

    fn corpus() -> Corpus {
        generate(&SyntheticConfig::small(), 5)
    }

    #[test]
    fn covers_all_documents_once() {
        let c = corpus();
        let cfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        let stream = CorpusStream::new(&c, cfg);
        let mut docs = 0usize;
        let mut mass = 0f64;
        for mb in stream {
            docs += mb.n_docs();
            mass += mb.docs.total_tokens();
        }
        assert_eq!(docs, c.n_docs());
        assert!((mass - c.n_tokens()).abs() < 1e-6);
    }

    #[test]
    fn batch_count_and_sizes() {
        let c = corpus();
        let cfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        let stream = CorpusStream::new(&c, cfg);
        assert_eq!(stream.batches_per_pass(), 200usize.div_ceil(64));
        let batches: Vec<_> = stream.collect();
        assert_eq!(batches.len(), 4);
        assert!(batches[..3].iter().all(|b| b.n_docs() == 64));
        assert_eq!(batches[3].n_docs(), 200 - 3 * 64);
        // indices are 1-based and increasing
        assert_eq!(batches[0].index, 1);
        assert_eq!(batches[3].index, 4);
    }

    #[test]
    fn local_vocab_matches_docs() {
        let c = corpus();
        let cfg = StreamConfig { minibatch_docs: 50, ..Default::default() };
        for mb in CorpusStream::new(&c, cfg) {
            let mut from_docs: Vec<u32> = mb.docs.word_ids.clone();
            from_docs.sort_unstable();
            from_docs.dedup();
            assert_eq!(from_docs, mb.local_words);
            // vocab-major columns only at local words
            for w in 0..mb.vocab_major.n_words {
                let nonempty = mb.vocab_major.word_docs(w).len() > 0;
                assert_eq!(nonempty, mb.local_words.binary_search(&(w as u32)).is_ok());
            }
        }
    }

    #[test]
    fn shard_partitions_documents_losslessly() {
        let c = corpus();
        let cfg = StreamConfig { minibatch_docs: 100, ..Default::default() };
        let mb = CorpusStream::new(&c, cfg).next().unwrap();
        for p in [1usize, 2, 3, 4, 7] {
            let shards = mb.shard(p);
            assert!(!shards.is_empty() && shards.len() <= p);
            assert_eq!(
                shards.iter().map(|s| s.n_docs()).sum::<usize>(),
                mb.n_docs()
            );
            let mass: f64 =
                shards.iter().map(|s| s.docs.total_tokens()).sum();
            assert!((mass - mb.docs.total_tokens()).abs() < 1e-6);
            let mut offset = 0usize;
            for (i, s) in shards.iter().enumerate() {
                assert_eq!(s.shard_index, i);
                assert_eq!(s.doc_offset, offset);
                offset += s.n_docs();
                // Shard rows are the minibatch's rows, in order.
                for d in 0..s.n_docs() {
                    assert_eq!(
                        s.docs.doc_words(d),
                        mb.docs.doc_words(s.doc_offset + d)
                    );
                    assert_eq!(
                        s.docs.doc_counts(d),
                        mb.docs.doc_counts(s.doc_offset + d)
                    );
                }
                // Per-shard vocab-major layout is consistent.
                assert_eq!(s.vocab_major.nnz(), s.docs.nnz());
                let mut from_docs: Vec<u32> = s.docs.word_ids.clone();
                from_docs.sort_unstable();
                from_docs.dedup();
                assert_eq!(from_docs, s.local_words);
                // Shard vocabulary ⊆ minibatch vocabulary.
                assert!(s
                    .local_words
                    .iter()
                    .all(|w| mb.local_words.binary_search(w).is_ok()));
            }
        }
    }

    #[test]
    fn shard_one_is_the_whole_minibatch() {
        let c = corpus();
        let cfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        let mb = CorpusStream::new(&c, cfg).next().unwrap();
        let shards = mb.shard(1);
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].docs, mb.docs);
        assert_eq!(shards[0].local_words, mb.local_words);
        assert_eq!(shards[0].doc_offset, 0);
    }

    #[test]
    fn shard_caps_at_document_count() {
        let c = corpus();
        let cfg = StreamConfig { minibatch_docs: 5, ..Default::default() };
        let mb = CorpusStream::new(&c, cfg).next().unwrap();
        let shards = mb.shard(16);
        assert_eq!(shards.len(), 5);
        assert!(shards.iter().all(|s| s.n_docs() == 1));
    }

    #[test]
    fn shuffle_changes_order_not_content() {
        let c = corpus();
        let plain: Vec<_> = CorpusStream::new(
            &c,
            StreamConfig { minibatch_docs: 32, shuffle: false, seed: 0 },
        )
        .collect();
        let shuf: Vec<_> = CorpusStream::new(
            &c,
            StreamConfig { minibatch_docs: 32, shuffle: true, seed: 9 },
        )
        .collect();
        let mass = |b: &[Minibatch]| -> f64 {
            b.iter().map(|m| m.docs.total_tokens()).sum()
        };
        assert!((mass(&plain) - mass(&shuf)).abs() < 1e-6);
        assert_ne!(plain[0].docs.word_ids, shuf[0].docs.word_ids);
    }

    #[test]
    fn lookahead_peeks_without_reordering() {
        let c = corpus();
        let cfg = StreamConfig { minibatch_docs: 50, ..Default::default() };
        let plain: Vec<_> = CorpusStream::new(&c, cfg).collect();
        let mut look = Lookahead::new(CorpusStream::new(&c, cfg), 2);
        let mut seen = Vec::new();
        while let Some(mb) = look.next() {
            // peek(i) must be exactly the batches next() will yield.
            for i in 0..2 {
                if let Some(up) = look.peek(i) {
                    assert_eq!(up.index, mb.index + i + 1);
                }
            }
            assert!(look.buffered() <= 2);
            seen.push(mb.index);
        }
        assert_eq!(
            seen,
            plain.iter().map(|b| b.index).collect::<Vec<_>>(),
            "lookahead must not reorder or drop batches"
        );
        assert_eq!(look.peek(0).map(|b| b.index), None);
    }

    #[test]
    fn lookahead_zero_is_a_plain_iterator() {
        let c = corpus();
        let cfg = StreamConfig { minibatch_docs: 64, ..Default::default() };
        let look = Lookahead::new(CorpusStream::new(&c, cfg), 0);
        assert!(look.peek(0).is_none());
        assert_eq!(look.count(), 4);
    }

    #[test]
    fn repeating_stream_keeps_counting() {
        let c = corpus();
        let cfg = StreamConfig { minibatch_docs: 100, ..Default::default() };
        let mut stream = RepeatingStream::new(&c, cfg);
        let batches: Vec<_> = (&mut stream).take(5).collect();
        assert_eq!(
            batches.iter().map(|b| b.index).collect::<Vec<_>>(),
            vec![1, 2, 3, 4, 5]
        );
        assert_eq!(stream.pass(), 2);
    }
}
