//! L3 coordinator: configuration, the training driver (stream → algorithm
//! → metrics → checkpoints), and run metrics.
//!
//! This is the layer a downstream user scripts against: pick a corpus,
//! pick an algorithm (FOEM or a baseline), pick a phi backend (in-memory
//! or disk-streamed), and drive the stream — the driver owns the loop,
//! periodic evaluation, and fault-tolerant checkpointing.

pub mod checkpoint;
pub mod config;
pub mod drift;
pub mod driver;
pub mod metrics;
