//! Online shift detection over the per-batch training signal.
//!
//! The paper's lifelong setting assumes the stream never ends; this
//! module assumes it never stays still either. A [`DriftMonitor`]
//! watches the per-token log-likelihood that every minibatch already
//! reports (`MinibatchReport::train_ll / tokens`) and flags abrupt
//! level shifts — the statistical signature of a regime change in the
//! underlying corpus (topic mixture shift, topic birth/death,
//! vocabulary growth; see `corpus::synthetic::DriftingCorpus` for the
//! ground-truth generator used to test this).
//!
//! Two detectors share one observation path:
//!
//! * **CUSUM** (the default when armed): a two-sided standardized
//!   cumulative-sum chart. Each observation is standardized against a
//!   *lagged* rolling window (the current observation is excluded from
//!   its own baseline), then accumulated into `g⁺ = max(0, g⁺ + z − κ)`
//!   and `g⁻ = max(0, g⁻ − z − κ)`. An alarm fires when either
//!   statistic crosses the threshold `h`.
//! * **Window** (Shewhart baseline): alarm when a single standardized
//!   observation satisfies `|z| ≥ h`. Less sensitive to small sustained
//!   shifts, immune to slow accumulation — kept as the control arm the
//!   CUSUM is benchmarked against in `benches/drift.rs`.
//!
//! Design notes (full discussion in rust/DESIGN.md §15):
//!
//! * The slack κ defaults to **2.0σ**. A converging trainer's LL
//!   improves steadily, and against a lagged window baseline a pure
//!   linear trend standardizes to z ≈ √12/2 ≈ 1.73 *independent of the
//!   noise scale* (both the lag of the mean and the within-window
//!   spread scale with the slope). Any κ below that accumulates the
//!   convergence ramp itself into a false "up" alarm; κ = 2 suppresses
//!   trends entirely while leaving genuine shifts (z ≫ κ) detected in
//!   ⌈h / (z̄ − κ)⌉ batches.
//! * σ has an absolute floor of 1e-12 — absolute, not relative, so the
//!   statistic stays invariant under a constant offset of the input
//!   series (`shift_prop_cusum_offset_invariant`).
//! * After an alarm the monitor discards its window and re-enters
//!   warmup: the post-shift regime needs a fresh baseline, and the
//!   warmup doubles as an alarm cooldown.
//!
//! The monitor is pure telemetry — it never touches the model. The
//! driver decides what to *do* about a confirmed shift via
//! [`ResponseKind`] (reset the n_d decay schedule, widen topic-subset
//! exploration, or grow K through the store seam); all of it is off by
//! default and bit-identity of the default path is enforced by
//! `tests/drift_equivalence.rs`.

use anyhow::{bail, Result};

/// Absolute floor for the baseline standard deviation. Keeps z finite
/// on degenerate (constant) windows without breaking offset invariance.
const MIN_SIGMA: f64 = 1e-12;

/// Sufficient-statistic discount applied by the `decay_reset` response:
/// `phi_hat *= γ`, `phisum *= γ`, which restarts the implicit 1/s
/// schedule at `s_eff = γ·s` (DESIGN.md §15).
pub const DECAY_FACTOR: f32 = 0.5;

/// Which change detector runs over the per-batch LL stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DetectorKind {
    /// No monitoring at all (the default): zero new code on the hot
    /// path, bit-identical to a build without this module.
    Off,
    /// Two-sided standardized CUSUM (Page's test).
    Cusum,
    /// Windowed-mean (Shewhart) baseline: single-observation z test.
    Window,
}

impl DetectorKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(Self::Off),
            "cusum" => Ok(Self::Cusum),
            "window" => Ok(Self::Window),
            other => bail!("unknown drift detector {other} (off|cusum|window)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::Cusum => "cusum",
            Self::Window => "window",
        }
    }
}

/// What the driver does when the detector confirms a shift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResponseKind {
    /// Record the event in telemetry but leave the model alone.
    None,
    /// Discount the accumulated sufficient statistics, restarting the
    /// implicit 1/s step-size schedule partway (DESIGN.md §15).
    DecayReset,
    /// Widen `TopicSubset` scheduling and exploration slots so the
    /// scheduler can rediscover topics the old residuals starved.
    Widen,
    /// Allocate fresh topics through the store seam (in-memory FOEM
    /// only — paged column records pin K at creation).
    Grow,
}

impl ResponseKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "none" => Ok(Self::None),
            "decay_reset" | "decay-reset" => Ok(Self::DecayReset),
            "widen" => Ok(Self::Widen),
            "grow" => Ok(Self::Grow),
            other => bail!("unknown drift response {other} (none|decay-reset|widen|grow)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::DecayReset => "decay_reset",
            Self::Widen => "widen",
            Self::Grow => "grow",
        }
    }
}

/// Which way the monitored statistic jumped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShiftDirection {
    /// Per-token LL abruptly improved (e.g. the stream got easier).
    Up,
    /// Per-token LL abruptly dropped — the classic drift signature.
    Down,
}

impl ShiftDirection {
    pub fn name(&self) -> &'static str {
        match self {
            Self::Up => "up",
            Self::Down => "down",
        }
    }
}

/// A confirmed change point, in stream batch coordinates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShiftEvent {
    /// Global batch index at which the alarm fired.
    pub batch: usize,
    pub direction: ShiftDirection,
    /// Value of the firing statistic: the winning CUSUM accumulator
    /// (≥ threshold) or |z| for the window detector.
    pub score: f64,
}

/// Detector tuning. Thresholds are in units of the baseline σ.
#[derive(Debug, Clone, Copy)]
pub struct MonitorConfig {
    pub detector: DetectorKind,
    /// Alarm threshold `h` (CUSUM accumulator / window |z|).
    pub threshold: f64,
    /// CUSUM slack κ subtracted from |z| before accumulation. Must
    /// exceed ~1.73 to ignore the convergence ramp (module docs).
    pub slack: f64,
    /// Rolling-baseline length in batches.
    pub window: usize,
    /// Observations absorbed before the detector arms; also the
    /// cooldown after every alarm.
    pub warmup: usize,
}

impl Default for MonitorConfig {
    fn default() -> Self {
        Self {
            detector: DetectorKind::Off,
            threshold: 8.0,
            slack: 2.0,
            window: 16,
            warmup: 12,
        }
    }
}

/// Online two-sided CUSUM / Shewhart monitor over a scalar series.
///
/// Feed it one observation per batch via [`DriftMonitor::observe`];
/// it returns `Some(ShiftEvent)` exactly when an alarm fires. All
/// state is plain f64 arithmetic — deterministic, RNG-free, and
/// independent of the model it watches.
#[derive(Debug, Clone)]
pub struct DriftMonitor {
    cfg: MonitorConfig,
    /// Lagged baseline: the last `window` observations *before* the
    /// one currently being scored.
    ring: Vec<f64>,
    /// Next slot to overwrite once the ring is full.
    next: usize,
    /// Observations absorbed since the last (re)arm.
    armed_count: usize,
    g_pos: f64,
    g_neg: f64,
    events: Vec<ShiftEvent>,
}

impl DriftMonitor {
    pub fn new(cfg: MonitorConfig) -> Self {
        Self {
            cfg,
            ring: Vec::with_capacity(cfg.window.max(2)),
            next: 0,
            armed_count: 0,
            g_pos: 0.0,
            g_neg: 0.0,
            events: Vec::new(),
        }
    }

    /// Mean and sample standard deviation of the lagged baseline.
    fn baseline(&self) -> (f64, f64) {
        let n = self.ring.len();
        let mean = self.ring.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            self.ring.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        (mean, var.sqrt())
    }

    /// True once warmup is over and the baseline has ≥ 2 points.
    pub fn is_armed(&self) -> bool {
        self.cfg.detector != DetectorKind::Off
            && self.armed_count >= self.cfg.warmup
            && self.ring.len() >= 2
    }

    /// Current value of the detection statistic (max CUSUM arm).
    pub fn statistic(&self) -> f64 {
        self.g_pos.max(self.g_neg)
    }

    /// Every alarm raised so far, in firing order.
    pub fn events(&self) -> &[ShiftEvent] {
        &self.events
    }

    /// Score one observation (per-token train LL of batch `batch`).
    ///
    /// Returns the alarm if one fired. The firing observation is NOT
    /// absorbed into the baseline — the monitor resets and re-warms on
    /// the post-shift regime instead.
    pub fn observe(&mut self, batch: usize, x: f64) -> Option<ShiftEvent> {
        if self.cfg.detector == DetectorKind::Off {
            return None;
        }
        let mut fired: Option<ShiftEvent> = None;
        if self.is_armed() {
            let (mean, std) = self.baseline();
            let z = (x - mean) / std.max(MIN_SIGMA);
            match self.cfg.detector {
                DetectorKind::Cusum => {
                    self.g_pos = (self.g_pos + z - self.cfg.slack).max(0.0);
                    self.g_neg = (self.g_neg - z - self.cfg.slack).max(0.0);
                    let g = self.statistic();
                    if g >= self.cfg.threshold {
                        let direction = if self.g_pos >= self.g_neg {
                            ShiftDirection::Up
                        } else {
                            ShiftDirection::Down
                        };
                        fired = Some(ShiftEvent { batch, direction, score: g });
                    }
                }
                DetectorKind::Window => {
                    if z.abs() >= self.cfg.threshold {
                        let direction = if z > 0.0 {
                            ShiftDirection::Up
                        } else {
                            ShiftDirection::Down
                        };
                        fired = Some(ShiftEvent { batch, direction, score: z.abs() });
                    }
                }
                DetectorKind::Off => unreachable!(),
            }
        }
        if let Some(event) = fired {
            self.events.push(event);
            self.ring.clear();
            self.next = 0;
            self.armed_count = 0;
            self.g_pos = 0.0;
            self.g_neg = 0.0;
            return Some(event);
        }
        if self.ring.len() < self.cfg.window.max(2) {
            self.ring.push(x);
        } else {
            self.ring[self.next] = x;
            self.next = (self.next + 1) % self.ring.len();
        }
        self.armed_count += 1;
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cusum_cfg() -> MonitorConfig {
        MonitorConfig { detector: DetectorKind::Cusum, ..MonitorConfig::default() }
    }

    #[test]
    fn off_detector_never_fires() {
        let mut m = DriftMonitor::new(MonitorConfig::default());
        for b in 0..200 {
            let x = if b < 100 { -5.0 } else { -50.0 };
            assert!(m.observe(b, x).is_none());
        }
        assert!(m.events().is_empty());
        assert!(!m.is_armed());
    }

    #[test]
    fn cusum_detects_level_drop() {
        let mut m = DriftMonitor::new(cusum_cfg());
        // Noisy-but-stationary prelude, then a brutal drop.
        let mut alarm = None;
        for b in 0..80 {
            let base = if b % 2 == 0 { -5.0 + 0.1 } else { -5.0 - 0.1 };
            let x = if b < 50 { base } else { base - 10.0 };
            if let Some(e) = m.observe(b, x) {
                alarm.get_or_insert(e);
            }
        }
        let e = alarm.expect("shift must be detected");
        assert!(e.batch >= 50 && e.batch < 58, "latency bound: fired at {}", e.batch);
        assert_eq!(e.direction, ShiftDirection::Down);
        assert!(e.score >= 8.0);
    }

    #[test]
    fn cusum_detects_level_rise_as_up() {
        let mut m = DriftMonitor::new(cusum_cfg());
        let mut alarm = None;
        for b in 0..80 {
            let base = if b % 2 == 0 { 0.1 } else { -0.1 };
            let x = if b < 50 { base } else { base + 10.0 };
            if let Some(e) = m.observe(b, x) {
                alarm.get_or_insert(e);
            }
        }
        assert_eq!(alarm.expect("detected").direction, ShiftDirection::Up);
    }

    #[test]
    fn window_detector_fires_on_outlier() {
        let cfg = MonitorConfig { detector: DetectorKind::Window, ..MonitorConfig::default() };
        let mut m = DriftMonitor::new(cfg);
        let mut alarm = None;
        for b in 0..60 {
            let base = if b % 2 == 0 { 0.1 } else { -0.1 };
            let x = if b < 40 { base } else { base - 20.0 };
            if let Some(e) = m.observe(b, x) {
                alarm.get_or_insert(e);
            }
        }
        let e = alarm.expect("detected");
        assert_eq!(e.batch, 40);
        assert_eq!(e.direction, ShiftDirection::Down);
    }

    #[test]
    fn convergence_ramp_does_not_alarm() {
        // Exponentially saturating improvement — the exact shape a
        // converging trainer emits — with κ = 2 must stay silent.
        let mut m = DriftMonitor::new(cusum_cfg());
        for b in 0..300 {
            let x = -5.0 - 2.0 * (-(b as f64) / 20.0).exp();
            assert!(m.observe(b, x).is_none(), "false alarm at batch {b}");
        }
        assert!(m.events().is_empty());
    }

    #[test]
    fn warmup_gates_arming_and_alarm_rearms() {
        let mut m = DriftMonitor::new(cusum_cfg());
        assert!(!m.is_armed());
        for b in 0..12 {
            m.observe(b, if b % 2 == 0 { 0.1 } else { -0.1 });
        }
        assert!(m.is_armed());
        // Force an alarm, then confirm full reset + cooldown.
        let e = (12..40).find_map(|b| m.observe(b, -50.0));
        let e = e.expect("alarm");
        assert!(!m.is_armed(), "must re-enter warmup after alarm");
        assert_eq!(m.statistic(), 0.0);
        // The cooldown swallows the next warmup-many observations even
        // though they sit far from the (discarded) old baseline.
        for b in e.batch + 1..e.batch + 1 + 12 {
            assert!(m.observe(b, -50.0 + (b % 2) as f64 * 0.1).is_none());
        }
    }

    #[test]
    fn kind_parsing_round_trips() {
        for k in [DetectorKind::Off, DetectorKind::Cusum, DetectorKind::Window] {
            assert_eq!(DetectorKind::parse(k.name()).unwrap(), k);
        }
        for r in [
            ResponseKind::None,
            ResponseKind::DecayReset,
            ResponseKind::Widen,
            ResponseKind::Grow,
        ] {
            assert_eq!(ResponseKind::parse(r.name()).unwrap(), r);
        }
        assert_eq!(ResponseKind::parse("decay-reset").unwrap(), ResponseKind::DecayReset);
        assert!(DetectorKind::parse("bogus").is_err());
        assert!(ResponseKind::parse("bogus").is_err());
    }
}
