//! Atomic trainer-state snapshots for crash-safe resumable training
//! (`--checkpoint-dir` / `--resume`, `rust/DESIGN.md` §13).
//!
//! A paged-store flush makes the *matrices* durable; this module makes
//! the *resident trainer* durable: step counter, coordinator RNG stream,
//! topic totals, residual totals, vocabulary-growth state, plus the
//! batch cursor and last published serving epoch. The snapshot is
//! written with the classic temp-file + fsync + rename + parent-fsync
//! dance, so a crash at any instant leaves either the old checkpoint or
//! the new one — never a torn file (a leftover `.tmp` is ignored by
//! [`load`] and overwritten by the next [`save`]).
//!
//! Every snapshot embeds an FNV-1a fingerprint of the numerics-affecting
//! [`RunConfig`] fields. Resuming under a different fingerprint would
//! silently break the determinism contract (a different stream order,
//! K, or kernel), so the driver rejects it with a clear error instead.

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::config::RunConfig;
use crate::em::foem::FoemTrainState;
use crate::store::wal;

const MAGIC: &[u8; 8] = b"FOEMCKP1";

/// Everything the driver needs to continue a run exactly where a
/// checkpoint left it.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainerCheckpoint {
    /// [`config_fingerprint`] of the run that wrote the snapshot.
    pub fingerprint: u64,
    /// Batches durably applied; the stream resumes after this cursor
    /// (plus whatever the WAL replays on top).
    pub batch_cursor: u64,
    /// Last serving epoch published before the snapshot — republished on
    /// resume so registry consumers never observe epoch regression.
    pub epoch: u64,
    /// Resident trainer state ([`FoemTrainState`]).
    pub state: FoemTrainState,
}

/// The snapshot lives at `<dir>/trainer.ckpt`.
pub fn checkpoint_path(dir: &Path) -> PathBuf {
    dir.join("trainer.ckpt")
}

/// FNV-1a hash of every [`RunConfig`] field that affects the training
/// numerics or the deterministic stream order. Presentation/cadence
/// knobs (eval/checkpoint/publish cadence, verbosity, buffer sizes,
/// pipeline depth — bit-identical by contract) are deliberately
/// excluded, so a resume may e.g. change the eval cadence but not K.
/// The vocabulary shard count IS included even though sharding is
/// content-identical: it pins the on-disk store layout.
pub fn config_fingerprint(cfg: &RunConfig) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    eat(cfg.algorithm.name().as_bytes());
    for v in [
        cfg.n_topics as u64,
        cfg.minibatch_docs as u64,
        cfg.passes as u64,
        cfg.lambda_k_topics as u64,
        cfg.hot_words as u64,
        cfg.n_workers as u64,
        // The shard layout is derived deterministically from
        // `n_shards` (even contiguous ranges), so the count pins the
        // on-disk partition: resuming with a different `--shards`
        // would reopen the wrong store files and is rejected here.
        cfg.n_shards as u64,
        cfg.seed,
    ] {
        eat(&v.to_le_bytes());
    }
    eat(&cfg.alpha.to_bits().to_le_bytes());
    eat(&cfg.beta.to_bits().to_le_bytes());
    eat(&cfg.lambda_w.to_bits().to_le_bytes());
    eat(&cfg.tau0.to_bits().to_le_bytes());
    eat(&cfg.kappa.to_bits().to_le_bytes());
    eat(format!("{:?}|{:?}", cfg.kernel_backend, cfg.phi_codec).as_bytes());
    h
}

/// Fail with an actionable error when `cfg` cannot continue the run
/// that wrote `ckpt`.
pub fn verify_compatible(
    ckpt: &TrainerCheckpoint,
    cfg: &RunConfig,
) -> Result<()> {
    let now = config_fingerprint(cfg);
    anyhow::ensure!(
        ckpt.fingerprint == now,
        "--resume config fingerprint {now:#018x} does not match the \
         checkpoint's {:#018x}: a numerics-affecting knob (algorithm, k, \
         alpha/beta, ds, passes, lambda, hot_words, workers, kernel, \
         codec, or seed) changed since the run being resumed",
        ckpt.fingerprint
    );
    Ok(())
}

/// Atomically write `<dir>/trainer.ckpt` (temp file + fsync + rename +
/// parent-directory fsync). Creates `dir` if needed.
pub fn save(dir: &Path, ckpt: &TrainerCheckpoint) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
    let mut b = Vec::new();
    b.extend_from_slice(MAGIC);
    b.extend_from_slice(&ckpt.fingerprint.to_le_bytes());
    b.extend_from_slice(&ckpt.batch_cursor.to_le_bytes());
    b.extend_from_slice(&ckpt.epoch.to_le_bytes());
    let st = &ckpt.state;
    b.extend_from_slice(&st.step.to_le_bytes());
    for s in st.rng {
        b.extend_from_slice(&s.to_le_bytes());
    }
    b.extend_from_slice(&(st.phisum.len() as u32).to_le_bytes());
    for &x in &st.phisum {
        b.extend_from_slice(&x.to_le_bytes());
    }
    b.extend_from_slice(&(st.r_totals.len() as u32).to_le_bytes());
    for &x in &st.r_totals {
        b.extend_from_slice(&x.to_le_bytes());
    }
    b.extend_from_slice(&(st.seen_words.len() as u32).to_le_bytes());
    for &w in &st.seen_words {
        b.extend_from_slice(&w.to_le_bytes());
    }
    let crc = wal::crc32(&b);
    b.extend_from_slice(&crc.to_le_bytes());

    let path = checkpoint_path(dir);
    let tmp = dir.join("trainer.ckpt.tmp");
    {
        let mut f = File::create(&tmp)
            .with_context(|| format!("creating {tmp:?}"))?;
        f.write_all(&b)?;
        f.sync_data()?;
    }
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    #[cfg(unix)]
    File::open(dir)?.sync_all()?;
    Ok(())
}

fn rd_u64(b: &[u8], p: &mut usize) -> Result<u64> {
    let s = b
        .get(*p..*p + 8)
        .ok_or_else(|| anyhow::anyhow!("trainer checkpoint truncated"))?;
    *p += 8;
    Ok(u64::from_le_bytes(s.try_into().unwrap()))
}

fn rd_u32(b: &[u8], p: &mut usize) -> Result<u32> {
    let s = b
        .get(*p..*p + 4)
        .ok_or_else(|| anyhow::anyhow!("trainer checkpoint truncated"))?;
    *p += 4;
    Ok(u32::from_le_bytes(s.try_into().unwrap()))
}

fn rd_f32_vec(b: &[u8], p: &mut usize) -> Result<Vec<f32>> {
    let n = rd_u32(b, p)? as usize;
    anyhow::ensure!(
        n <= b.len().saturating_sub(*p) / 4,
        "trainer checkpoint truncated: claims {n} f32 entries"
    );
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        v.push(f32::from_bits(rd_u32(b, p)?));
    }
    Ok(v)
}

/// Read `<dir>/trainer.ckpt`. `Ok(None)` when no checkpoint exists yet;
/// an error on any corruption (bad magic, short file, CRC mismatch) —
/// a torn checkpoint is impossible by construction, so corruption means
/// something external damaged the file and silently starting over would
/// hide it.
pub fn load(dir: &Path) -> Result<Option<TrainerCheckpoint>> {
    let path = checkpoint_path(dir);
    let bytes = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(None)
        }
        Err(e) => {
            return Err(e).context(format!("reading checkpoint {path:?}"))
        }
    };
    anyhow::ensure!(
        bytes.len() >= MAGIC.len() + 4,
        "trainer checkpoint {path:?} truncated"
    );
    anyhow::ensure!(
        &bytes[..MAGIC.len()] == MAGIC,
        "{path:?} is not a trainer checkpoint (bad magic)"
    );
    let body = bytes.len() - 4;
    let stored = u32::from_le_bytes(bytes[body..].try_into().unwrap());
    anyhow::ensure!(
        wal::crc32(&bytes[..body]) == stored,
        "trainer checkpoint {path:?} corrupt (CRC mismatch)"
    );
    let b = &bytes[..body];
    let mut p = MAGIC.len();
    let fingerprint = rd_u64(b, &mut p)?;
    let batch_cursor = rd_u64(b, &mut p)?;
    let epoch = rd_u64(b, &mut p)?;
    let step = rd_u64(b, &mut p)?;
    let mut rng = [0u64; 4];
    for s in &mut rng {
        *s = rd_u64(b, &mut p)?;
    }
    let phisum = rd_f32_vec(b, &mut p)?;
    let r_totals = rd_f32_vec(b, &mut p)?;
    let n = rd_u32(b, &mut p)? as usize;
    anyhow::ensure!(
        n <= b.len().saturating_sub(p) / 4,
        "trainer checkpoint truncated: claims {n} seen words"
    );
    let mut seen_words = Vec::with_capacity(n);
    for _ in 0..n {
        seen_words.push(rd_u32(b, &mut p)?);
    }
    Ok(Some(TrainerCheckpoint {
        fingerprint,
        batch_cursor,
        epoch,
        state: FoemTrainState { step, rng, phisum, r_totals, seen_words },
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TrainerCheckpoint {
        TrainerCheckpoint {
            fingerprint: config_fingerprint(&RunConfig::default()),
            batch_cursor: 7,
            epoch: 3,
            state: FoemTrainState {
                step: 7,
                rng: [1, u64::MAX, 3, 4],
                phisum: vec![1.5, 0.0, 2.25],
                r_totals: vec![0.5, 4.0],
                seen_words: vec![0, 1, 5],
            },
        }
    }

    #[test]
    fn recovery_checkpoint_roundtrips_exactly() {
        let dir = crate::util::TempDir::new("ckpt");
        let ckpt = sample();
        save(dir.path(), &ckpt).unwrap();
        assert_eq!(load(dir.path()).unwrap().unwrap(), ckpt);
        // Overwrites atomically: the second save replaces the first.
        let mut ckpt2 = ckpt.clone();
        ckpt2.batch_cursor = 9;
        save(dir.path(), &ckpt2).unwrap();
        assert_eq!(load(dir.path()).unwrap().unwrap(), ckpt2);
    }

    #[test]
    fn recovery_missing_checkpoint_is_none() {
        let dir = crate::util::TempDir::new("ckpt-none");
        assert_eq!(load(dir.path()).unwrap(), None);
    }

    #[test]
    fn recovery_leftover_temp_file_is_ignored() {
        // A crash between temp-write and rename leaves `.tmp` garbage;
        // load must see the (old or absent) real checkpoint, and the
        // next save must clobber the leftover.
        let dir = crate::util::TempDir::new("ckpt-tmp");
        std::fs::write(dir.path().join("trainer.ckpt.tmp"), b"garbage")
            .unwrap();
        assert_eq!(load(dir.path()).unwrap(), None);
        let ckpt = sample();
        save(dir.path(), &ckpt).unwrap();
        assert_eq!(load(dir.path()).unwrap().unwrap(), ckpt);
        assert!(!dir.path().join("trainer.ckpt.tmp").exists());
    }

    #[test]
    fn recovery_corrupt_checkpoint_rejected() {
        let dir = crate::util::TempDir::new("ckpt-bad");
        save(dir.path(), &sample()).unwrap();
        let p = checkpoint_path(dir.path());
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&p, &bytes).unwrap();
        let err = load(dir.path()).unwrap_err();
        assert!(err.to_string().contains("CRC"), "{err}");
        // Truncation is caught too (CRC trailer goes missing).
        std::fs::write(&p, &bytes[..10]).unwrap();
        assert!(load(dir.path()).is_err());
    }

    #[test]
    fn recovery_fingerprint_tracks_numerics_only() {
        let base = RunConfig::default();
        let fp = config_fingerprint(&base);
        let mut c = base.clone();
        c.seed = 43;
        assert_ne!(config_fingerprint(&c), fp, "seed must change it");
        let mut c = base.clone();
        c.n_topics = 64;
        assert_ne!(config_fingerprint(&c), fp, "K must change it");
        // Cadence/presentation knobs must NOT change it: a resume may
        // alter them freely.
        let mut c = base.clone();
        c.eval_every = 50;
        c.checkpoint_every = 10;
        c.verbose = true;
        c.pipeline_depth = 2;
        assert_eq!(config_fingerprint(&c), fp);
    }

    #[test]
    fn recovery_mismatched_config_rejected() {
        let ckpt = sample();
        let mut c = RunConfig::default();
        verify_compatible(&ckpt, &c).unwrap();
        c.n_workers = 4;
        let err = verify_compatible(&ckpt, &c).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
    }
}
