//! Run configuration: every knob of a training run, parseable from a
//! simple `key value` config file plus command-line overrides (the
//! dependency-light stand-in for a clap/serde config system — the
//! vendored crate set has neither).

use crate::coordinator::drift::{DetectorKind, MonitorConfig, ResponseKind};
use crate::em::foem::FoemConfig;
use crate::em::schedule::TopicSubset;
use crate::em::sem::LearningRate;
use crate::em::simd::KernelBackend;
use anyhow::{Context, Result};
use std::path::PathBuf;

/// Which algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    Foem,
    Sem,
    Scvb,
    Ovb,
    Ogs,
    Rvb,
    Soi,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "foem" => Self::Foem,
            "sem" => Self::Sem,
            "scvb" => Self::Scvb,
            "ovb" => Self::Ovb,
            "ogs" => Self::Ogs,
            "rvb" => Self::Rvb,
            "soi" => Self::Soi,
            other => anyhow::bail!("unknown algorithm {other}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Foem => "FOEM",
            Self::Sem => "SEM",
            Self::Scvb => "SCVB",
            Self::Ovb => "OVB",
            Self::Ogs => "OGS",
            Self::Rvb => "RVB",
            Self::Soi => "SOI",
        }
    }

    pub fn all() -> [Algorithm; 7] {
        [
            Self::Foem,
            Self::Ogs,
            Self::Scvb,
            Self::Sem,
            Self::Ovb,
            Self::Rvb,
            Self::Soi,
        ]
    }
}

/// Phi storage backend selection.
#[derive(Debug, Clone, PartialEq)]
pub enum StoreKind {
    InMemory,
    /// Disk-streamed with a hot buffer of `buffer_bytes`.
    Paged { path: PathBuf, buffer_bytes: usize },
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub algorithm: Algorithm,
    pub n_topics: usize,
    /// MAP hyperparameters (EM family): alpha-1 = beta-1 = 0.01.
    pub alpha: f32,
    pub beta: f32,
    /// Minibatch size D_s.
    pub minibatch_docs: usize,
    /// Passes over the corpus (1 = pure single-look stream).
    pub passes: usize,
    /// Learning-rate schedule for the stepwise family.
    pub tau0: f64,
    pub kappa: f64,
    pub store: StoreKind,
    /// FOEM scheduling: lambda_k K topics per word (0 = all).
    pub lambda_k_topics: usize,
    pub lambda_w: f32,
    /// FOEM hot-word pinning per minibatch.
    pub hot_words: usize,
    /// Evaluate predictive perplexity every N minibatches (0 = only at
    /// the end).
    pub eval_every: usize,
    /// Checkpoint (paged store only) every N minibatches (0 = never).
    pub checkpoint_every: usize,
    /// Directory for atomic trainer-state snapshots
    /// (`--checkpoint-dir`). When set (FOEM + paged store only), every
    /// `checkpoint_every` minibatches the driver flushes the stores,
    /// writes `trainer.ckpt` via temp-file + rename, and truncates the
    /// write-ahead logs. Required for `resume`.
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume a crashed/killed run from `checkpoint_dir` (`--resume`):
    /// restore the trainer snapshot, replay WAL-committed batches, and
    /// continue the stream after the recovered batch cursor. The run
    /// configuration must fingerprint-match the checkpoint's.
    pub resume: bool,
    /// Arm the per-batch write-ahead log on the paged stores (`--wal`).
    /// Implied by `checkpoint_dir`; off by default so existing configs
    /// keep byte-identical store files.
    pub wal: bool,
    /// E-step worker threads for the parallel executor (FOEM and SEM
    /// route minibatches through `exec::ParallelExecutor`); `1` keeps the
    /// exact serial path.
    pub n_workers: usize,
    /// Vocabulary shards (`--shards`, FOEM + paged store only): `0`
    /// keeps the single-store path; `N >= 1` partitions the vocabulary
    /// into N contiguous ranges, each owned by a phi-shard thread with
    /// its own paged store pair, WAL and checkpoint
    /// ([`crate::shard`]). `N = 1` is bit-identical to the unsharded
    /// run; the shard layout is part of the checkpoint fingerprint, so
    /// `resume` rejects a changed shard count.
    pub n_shards: usize,
    /// Software-pipeline depth (`exec::pipeline`): how many minibatches
    /// may be staged/computing ahead of the strict-order apply cursor.
    /// `0` bypasses the pipeline entirely — bit-identical to the plain
    /// trainer loop (numerics and IoStats). `>= 1` overlaps store
    /// prefetch and write-behind with compute (FOEM and SEM only).
    pub pipeline_depth: usize,
    /// Topics scheduled per document by the fold-in inference engine
    /// during periodic/final evaluation (`em::infer`); `0` = all K (the
    /// historical dense protocol). The default mirrors FOEM's production
    /// `lambda_k*K = 10`, so evaluation cost scales with NNZ·S instead
    /// of NNZ·K.
    pub fold_in_subset: usize,
    /// Worker threads for fold-in evaluation (documents are independent
    /// given a frozen phi, so this parallelizes embarrassingly).
    pub fold_in_workers: usize,
    /// Publish an epoch-tagged serving snapshot to the driver's attached
    /// [`crate::serve::ModelRegistry`] every N minibatches, plus once at
    /// the end of the run (0 = never publish). No effect unless a
    /// registry is attached (`Driver::with_registry`).
    pub serve_publish_every: usize,
    /// Most requests the serving batcher coalesces into one dispatched
    /// inference minibatch (must be >= 1).
    pub serve_batch_docs: usize,
    /// Bound of the serving request queue — the backpressure knob (must
    /// be >= 1).
    pub serve_queue_docs: usize,
    /// Worker threads a serving batch fans out over.
    pub serve_workers: usize,
    /// Topics scheduled per document by the serving fold-in (`0` = all K,
    /// the dense reference protocol) — mirrors `fold_in_subset`.
    pub serve_subset: usize,
    /// On-disk column encoding policy for the paged phi/residual stores
    /// (`--phi-codec`): `raw` (the bit-identity reference format),
    /// `sparse`, `rle`, or `auto` (per-column smallest-wins, the
    /// default). Every codec is lossless, so this changes bytes on disk
    /// and nothing else; ignored by the in-memory store.
    pub phi_codec: crate::store::Codec,
    /// E-step kernel backend: `scalar` (the bit-identity reference),
    /// `simd` (force the vector tiers), or `auto` (AVX2+FMA where
    /// detected, scalar otherwise). Threaded through every consumer of
    /// the shared sweep kernel — training, fold-in, and serving.
    pub kernel_backend: KernelBackend,
    /// Online shift detection over the per-batch training LL
    /// (`--drift-detector off|cusum|window`, coordinator::drift). Off by
    /// default: the detector-off path is bit-identical to a build
    /// without the drift subsystem. Turning it on forces the exact
    /// training-LL pass (`exact_ll`) so the monitor has a signal —
    /// a read-only, RNG-free pass, so model state stays bit-identical
    /// and only telemetry changes.
    pub drift_detector: DetectorKind,
    /// What the driver does on a confirmed shift
    /// (`--drift-response none|decay-reset|widen|grow`). Responses
    /// mutate the model mid-stream, so they require `pipeline_depth 0`;
    /// `grow` additionally requires FOEM on the in-memory store.
    pub drift_response: ResponseKind,
    /// Detector alarm threshold `h`, in baseline-σ units.
    pub drift_threshold: f64,
    /// CUSUM slack `κ` (per-batch drift allowance, in baseline-σ
    /// units). The default 2.0 sits above the ~1.73σ a smooth
    /// convergence trend standardizes to against the lagged window
    /// baseline, so converging-but-stationary streams never alarm
    /// (see `coordinator::drift`); lower it only to make the detector
    /// deliberately jumpy (e.g. in tests).
    pub drift_slack: f64,
    /// Rolling-baseline window, in batches.
    pub drift_window: usize,
    /// Batches absorbed before the detector arms (also the post-alarm
    /// cooldown).
    pub drift_warmup: usize,
    /// Fresh topics allocated by the `grow` response per shift.
    pub drift_grow_topics: usize,
    pub seed: u64,
    /// Print per-minibatch progress lines.
    pub verbose: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            algorithm: Algorithm::Foem,
            n_topics: 100,
            alpha: 1.01,
            beta: 1.01,
            minibatch_docs: 1024,
            passes: 1,
            tau0: 1024.0,
            kappa: 0.5,
            store: StoreKind::InMemory,
            lambda_k_topics: 10,
            lambda_w: 1.0,
            hot_words: 0,
            eval_every: 0,
            checkpoint_every: 0,
            checkpoint_dir: None,
            resume: false,
            wal: false,
            n_workers: 1,
            n_shards: 0,
            pipeline_depth: 0,
            fold_in_subset: 10,
            fold_in_workers: 1,
            serve_publish_every: 0,
            serve_batch_docs: 32,
            serve_queue_docs: 256,
            serve_workers: 1,
            serve_subset: 10,
            phi_codec: crate::store::Codec::Auto,
            kernel_backend: KernelBackend::Scalar,
            drift_detector: DetectorKind::Off,
            drift_response: ResponseKind::None,
            drift_threshold: 8.0,
            drift_slack: 2.0,
            drift_window: 16,
            drift_warmup: 12,
            drift_grow_topics: 8,
            seed: 42,
            verbose: false,
        }
    }
}

impl RunConfig {
    pub fn params(&self) -> crate::LdaParams {
        crate::LdaParams {
            n_topics: self.n_topics,
            alpha: self.alpha,
            beta: self.beta,
        }
    }

    pub fn rate(&self) -> LearningRate {
        LearningRate { tau0: self.tau0, kappa: self.kappa }
    }

    pub fn foem_config(&self) -> FoemConfig {
        FoemConfig {
            topic_subset: if self.lambda_k_topics == 0 {
                TopicSubset::All
            } else {
                TopicSubset::Fixed(self.lambda_k_topics)
            },
            lambda_w: self.lambda_w,
            hot_words: self.hot_words,
            // The driver evaluates predictively (eval_every); skip the
            // O(K*NNZ_s) exact-training-LL pass on the hot path so the
            // per-minibatch cost stays flat in K (Table 3). The drift
            // monitor's observation IS the per-batch training LL, so an
            // armed detector turns the pass back on — it is read-only
            // and RNG-free, so model state stays bit-identical.
            exact_ll: self.drift_detector != DetectorKind::Off,
            n_workers: self.n_workers,
            kernel_backend: self.kernel_backend,
            ..FoemConfig::paper()
        }
    }

    /// The drift-monitor tuning this run configuration induces
    /// ([`crate::coordinator::drift::DriftMonitor`]).
    pub fn monitor_config(&self) -> MonitorConfig {
        MonitorConfig {
            detector: self.drift_detector,
            threshold: self.drift_threshold,
            slack: self.drift_slack,
            window: self.drift_window,
            warmup: self.drift_warmup,
        }
    }

    /// The evaluation protocol of the driver's periodic/final predictive
    /// perplexity: 30 fold-in sweeps through the configured fold-in
    /// subset/workers. Scheduled subsets run with the per-document
    /// convergence cutoff on; `fold_in_subset == 0` disables the cutoff
    /// too, so it reproduces the historical dense protocol exactly
    /// (full budget, no skipping — the `em::infer` bitwise-reference
    /// configuration). Shared by the plain and pipelined run loops so
    /// they cannot drift.
    pub fn eval_protocol(&self) -> crate::eval::EvalProtocol {
        let (subset, tol) = if self.fold_in_subset == 0 {
            (TopicSubset::All, 0.0)
        } else {
            (TopicSubset::Fixed(self.fold_in_subset), 1e-2)
        };
        crate::eval::EvalProtocol {
            fold_in_iters: 30,
            seed: self.seed,
            subset,
            tol,
            workers: self.fold_in_workers.max(1),
            kernel_backend: self.kernel_backend,
            ..Default::default()
        }
    }

    /// The serving policy ([`crate::serve::ServeConfig`]) this run
    /// configuration induces: 30 fold-in sweeps per request through the
    /// configured serving subset/workers. `serve_subset == 0` selects
    /// the dense reference protocol (full K, no convergence cutoff —
    /// the `em::infer` bitwise-reference configuration), mirroring
    /// [`RunConfig::eval_protocol`] so the two paths cannot drift.
    pub fn serve_config(&self) -> crate::serve::ServeConfig {
        use crate::em::infer::FoldInConfig;
        let (subset, tol) = if self.serve_subset == 0 {
            (TopicSubset::All, 0.0)
        } else {
            (TopicSubset::Fixed(self.serve_subset), 1e-2)
        };
        crate::serve::ServeConfig {
            max_batch_docs: self.serve_batch_docs.max(1),
            queue_docs: self.serve_queue_docs.max(1),
            workers: self.serve_workers.max(1),
            fold_in: FoldInConfig {
                subset,
                explore_slots: 2,
                max_sweeps: 30,
                tol,
                n_workers: 1,
                kernel_backend: self.kernel_backend,
            },
        }
    }

    /// Apply one `key value` pair (config file line or `--key value`).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "algorithm" => self.algorithm = Algorithm::parse(value)?,
            "n_topics" | "k" => self.n_topics = value.parse()?,
            "alpha" => self.alpha = value.parse()?,
            "beta" => self.beta = value.parse()?,
            "minibatch_docs" | "ds" => self.minibatch_docs = value.parse()?,
            "passes" => self.passes = value.parse()?,
            "tau0" => self.tau0 = value.parse()?,
            "kappa" => self.kappa = value.parse()?,
            "lambda_k_topics" => self.lambda_k_topics = value.parse()?,
            "lambda_w" => self.lambda_w = value.parse()?,
            "hot_words" => self.hot_words = value.parse()?,
            "eval_every" => self.eval_every = value.parse()?,
            "checkpoint_every" => self.checkpoint_every = value.parse()?,
            "checkpoint_dir" => {
                self.checkpoint_dir = Some(PathBuf::from(value))
            }
            "resume" => self.resume = value.parse()?,
            "wal" => self.wal = value.parse()?,
            "n_workers" | "workers" => self.n_workers = value.parse()?,
            "n_shards" | "shards" => self.n_shards = value.parse()?,
            "pipeline_depth" => self.pipeline_depth = value.parse()?,
            "fold_in_subset" => self.fold_in_subset = value.parse()?,
            "fold_in_workers" => self.fold_in_workers = value.parse()?,
            "serve_publish_every" => {
                self.serve_publish_every = value.parse()?
            }
            "serve_batch_docs" => {
                let n: usize = value.parse()?;
                anyhow::ensure!(n >= 1, "serve_batch_docs must be >= 1");
                self.serve_batch_docs = n;
            }
            "serve_queue_docs" => {
                let n: usize = value.parse()?;
                anyhow::ensure!(n >= 1, "serve_queue_docs must be >= 1");
                self.serve_queue_docs = n;
            }
            "serve_workers" => self.serve_workers = value.parse()?,
            "serve_subset" => self.serve_subset = value.parse()?,
            "phi_codec" => {
                self.phi_codec =
                    crate::store::Codec::parse(value).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown phi codec {value} \
                             (expected raw|sparse|rle|auto)"
                        )
                    })?;
            }
            "kernel_backend" => {
                self.kernel_backend = KernelBackend::parse(value)?
            }
            "drift_detector" => {
                self.drift_detector = DetectorKind::parse(value)?
            }
            "drift_response" => {
                self.drift_response = ResponseKind::parse(value)?
            }
            "drift_threshold" => {
                let h: f64 = value.parse()?;
                anyhow::ensure!(h > 0.0, "drift_threshold must be > 0");
                self.drift_threshold = h;
            }
            "drift_slack" => {
                let s: f64 = value.parse()?;
                anyhow::ensure!(s >= 0.0, "drift_slack must be >= 0");
                self.drift_slack = s;
            }
            "drift_window" => {
                let w: usize = value.parse()?;
                anyhow::ensure!(w >= 2, "drift_window must be >= 2");
                self.drift_window = w;
            }
            "drift_warmup" => self.drift_warmup = value.parse()?,
            "drift_grow_topics" => {
                let n: usize = value.parse()?;
                anyhow::ensure!(n >= 1, "drift_grow_topics must be >= 1");
                self.drift_grow_topics = n;
            }
            "seed" => self.seed = value.parse()?,
            "verbose" => self.verbose = value.parse()?,
            "store" => {
                self.store = if value == "memory" {
                    StoreKind::InMemory
                } else {
                    anyhow::bail!(
                        "store must be `memory` or set via store_path/buffer_mb"
                    )
                }
            }
            "store_path" => {
                let buffer = match &self.store {
                    StoreKind::Paged { buffer_bytes, .. } => *buffer_bytes,
                    _ => 256 << 20,
                };
                self.store = StoreKind::Paged {
                    path: PathBuf::from(value),
                    buffer_bytes: buffer,
                };
            }
            "buffer_mb" => {
                let bytes = value.parse::<usize>()? << 20;
                self.store = match std::mem::replace(
                    &mut self.store,
                    StoreKind::InMemory,
                ) {
                    StoreKind::Paged { path, .. } => {
                        StoreKind::Paged { path, buffer_bytes: bytes }
                    }
                    StoreKind::InMemory => StoreKind::Paged {
                        path: PathBuf::from("phi_store.bin"),
                        buffer_bytes: bytes,
                    },
                };
            }
            other => anyhow::bail!("unknown config key {other}"),
        }
        Ok(())
    }

    /// Parse a config file of `key value` lines (# comments allowed).
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let mut cfg = Self::default();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path:?}"))?;
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (key, value) = line
                .split_once(char::is_whitespace)
                .with_context(|| format!("line {}: expected `key value`", ln + 1))?;
            cfg.set(key, value.trim())
                .with_context(|| format!("line {}", ln + 1))?;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = RunConfig::default();
        assert_eq!(c.minibatch_docs, 1024);
        assert!((c.alpha - 1.01).abs() < 1e-6);
        assert_eq!(c.lambda_k_topics, 10);
        assert_eq!(c.tau0, 1024.0);
        assert_eq!(c.kappa, 0.5);
    }

    #[test]
    fn set_overrides() {
        let mut c = RunConfig::default();
        c.set("algorithm", "ovb").unwrap();
        c.set("k", "250").unwrap();
        c.set("ds", "512").unwrap();
        c.set("n_workers", "4").unwrap();
        assert_eq!(c.algorithm, Algorithm::Ovb);
        assert_eq!(c.n_topics, 250);
        assert_eq!(c.minibatch_docs, 512);
        assert_eq!(c.n_workers, 4);
        c.set("workers", "2").unwrap();
        assert_eq!(c.n_workers, 2);
        c.set("pipeline_depth", "3").unwrap();
        assert_eq!(c.pipeline_depth, 3);
        c.set("fold_in_subset", "16").unwrap();
        c.set("fold_in_workers", "4").unwrap();
        assert_eq!(c.fold_in_subset, 16);
        assert_eq!(c.fold_in_workers, 4);
        assert!(c.set("bogus", "1").is_err());
    }

    #[test]
    fn codec_config_round_trip() {
        let mut c = RunConfig::default();
        assert_eq!(c.phi_codec, crate::store::Codec::Auto, "default is auto");
        for (name, codec) in [
            ("raw", crate::store::Codec::Raw),
            ("sparse", crate::store::Codec::Sparse),
            ("rle", crate::store::Codec::Rle),
            ("auto", crate::store::Codec::Auto),
        ] {
            c.set("phi_codec", name).unwrap();
            assert_eq!(c.phi_codec, codec);
        }
        assert!(c.set("phi_codec", "zstd").is_err());
    }

    #[test]
    fn eval_protocol_reflects_fold_in_knobs() {
        use crate::em::schedule::TopicSubset;
        let mut c = RunConfig::default();
        let proto = c.eval_protocol();
        assert_eq!(proto.subset, TopicSubset::Fixed(10));
        assert_eq!(proto.workers, 1);
        assert_eq!(proto.seed, c.seed);
        assert!(proto.tol > 0.0);
        c.set("fold_in_subset", "0").unwrap();
        c.set("fold_in_workers", "3").unwrap();
        let proto = c.eval_protocol();
        assert_eq!(proto.subset, TopicSubset::All);
        assert_eq!(proto.workers, 3);
        // subset 0 must reproduce the historical dense protocol exactly:
        // no convergence cutoff, full sweep budget.
        assert_eq!(proto.tol, 0.0);
    }

    #[test]
    fn serve_knobs_round_trip() {
        use crate::em::schedule::TopicSubset;
        let mut c = RunConfig::default();
        // Defaults: publishing off, sane batching, paper-shaped subset.
        assert_eq!(c.serve_publish_every, 0);
        assert_eq!(c.serve_batch_docs, 32);
        assert_eq!(c.serve_queue_docs, 256);
        assert_eq!(c.serve_workers, 1);
        assert_eq!(c.serve_subset, 10);
        c.set("serve_publish_every", "5").unwrap();
        c.set("serve_batch_docs", "16").unwrap();
        c.set("serve_queue_docs", "64").unwrap();
        c.set("serve_workers", "4").unwrap();
        c.set("serve_subset", "8").unwrap();
        assert_eq!(c.serve_publish_every, 5);
        let sc = c.serve_config();
        assert_eq!(sc.max_batch_docs, 16);
        assert_eq!(sc.queue_docs, 64);
        assert_eq!(sc.workers, 4);
        assert_eq!(sc.fold_in.subset, TopicSubset::Fixed(8));
        assert_eq!(sc.fold_in.n_workers, 1, "per-request fold-in is serial");
        assert!(sc.fold_in.tol > 0.0);
        // subset 0 must reproduce the dense reference protocol exactly:
        // full K, no convergence cutoff (mirrors eval_protocol).
        c.set("serve_subset", "0").unwrap();
        let sc = c.serve_config();
        assert_eq!(sc.fold_in.subset, TopicSubset::All);
        assert_eq!(sc.fold_in.tol, 0.0);
    }

    #[test]
    fn serve_knob_invalid_values_error() {
        let mut c = RunConfig::default();
        assert!(c.set("serve_batch_docs", "0").is_err());
        assert!(c.set("serve_queue_docs", "0").is_err());
        assert!(c.set("serve_publish_every", "abc").is_err());
        assert!(c.set("serve_workers", "-1").is_err());
        assert!(c.set("serve_subset", "1.5").is_err());
        // Failed sets leave the config untouched.
        assert_eq!(c.serve_batch_docs, 32);
        assert_eq!(c.serve_queue_docs, 256);
        assert_eq!(c.serve_workers, 1);
        assert_eq!(c.serve_subset, 10);
    }

    #[test]
    fn kernel_backend_round_trips() {
        let mut c = RunConfig::default();
        assert_eq!(c.kernel_backend, KernelBackend::Scalar);
        c.set("kernel_backend", "simd").unwrap();
        assert_eq!(c.kernel_backend, KernelBackend::Simd);
        c.set("kernel_backend", "auto").unwrap();
        assert_eq!(c.kernel_backend, KernelBackend::Auto);
        assert!(c.set("kernel_backend", "neon").is_err());
        // The knob threads through every kernel consumer.
        assert_eq!(c.foem_config().kernel_backend, KernelBackend::Auto);
        assert_eq!(c.eval_protocol().kernel_backend, KernelBackend::Auto);
        assert_eq!(
            c.serve_config().fold_in.kernel_backend,
            KernelBackend::Auto
        );
    }

    #[test]
    fn recovery_knobs_round_trip() {
        let mut c = RunConfig::default();
        // Defaults keep existing runs byte-identical: no WAL, no
        // checkpoint dir, no resume.
        assert_eq!(c.checkpoint_dir, None);
        assert!(!c.resume);
        assert!(!c.wal);
        c.set("checkpoint_dir", "/tmp/ckpt").unwrap();
        c.set("resume", "true").unwrap();
        c.set("wal", "true").unwrap();
        assert_eq!(c.checkpoint_dir, Some(PathBuf::from("/tmp/ckpt")));
        assert!(c.resume);
        assert!(c.wal);
        assert!(c.set("resume", "maybe").is_err());
    }

    #[test]
    fn drift_knobs_round_trip() {
        let mut c = RunConfig::default();
        // Defaults: detector off, no response, and — critically for the
        // bit-identity contract — foem_config unchanged from pre-drift
        // behavior (no exact-LL pass).
        assert_eq!(c.drift_detector, DetectorKind::Off);
        assert_eq!(c.drift_response, ResponseKind::None);
        assert!(!c.foem_config().exact_ll);
        assert_eq!(c.monitor_config().detector, DetectorKind::Off);
        c.set("drift_detector", "cusum").unwrap();
        c.set("drift_response", "decay-reset").unwrap();
        c.set("drift_threshold", "5.5").unwrap();
        c.set("drift_slack", "0.5").unwrap();
        c.set("drift_window", "24").unwrap();
        c.set("drift_warmup", "6").unwrap();
        c.set("drift_grow_topics", "4").unwrap();
        assert_eq!(c.drift_detector, DetectorKind::Cusum);
        assert_eq!(c.drift_response, ResponseKind::DecayReset);
        assert_eq!(c.drift_grow_topics, 4);
        let m = c.monitor_config();
        assert_eq!(m.detector, DetectorKind::Cusum);
        assert_eq!(m.threshold, 5.5);
        assert_eq!(m.slack, 0.5);
        assert_eq!(m.window, 24);
        assert_eq!(m.warmup, 6);
        // An armed detector needs the training-LL signal.
        assert!(c.foem_config().exact_ll);
        c.set("drift_detector", "window").unwrap();
        assert_eq!(c.drift_detector, DetectorKind::Window);
        assert!(c.set("drift_detector", "bogus").is_err());
        assert!(c.set("drift_response", "panic").is_err());
        assert!(c.set("drift_threshold", "0").is_err());
        assert!(c.set("drift_slack", "-1").is_err());
        assert!(c.set("drift_window", "1").is_err());
        assert!(c.set("drift_grow_topics", "0").is_err());
    }

    #[test]
    fn paged_store_composition() {
        let mut c = RunConfig::default();
        c.set("store_path", "/tmp/phi.bin").unwrap();
        c.set("buffer_mb", "2").unwrap();
        match &c.store {
            StoreKind::Paged { path, buffer_bytes } => {
                assert_eq!(path, &PathBuf::from("/tmp/phi.bin"));
                assert_eq!(*buffer_bytes, 2 << 20);
            }
            _ => panic!("expected paged store"),
        }
    }

    #[test]
    fn from_file_round_trip() {
        let dir = crate::util::TempDir::new("cfg");
        let p = dir.path().join("run.conf");
        std::fs::write(
            &p,
            "# experiment\nalgorithm foem\nk 64\nds 256\nlambda_k_topics 5\n",
        )
        .unwrap();
        let c = RunConfig::from_file(&p).unwrap();
        assert_eq!(c.algorithm, Algorithm::Foem);
        assert_eq!(c.n_topics, 64);
        assert_eq!(c.minibatch_docs, 256);
        assert_eq!(c.lambda_k_topics, 5);
    }

    #[test]
    fn algorithm_names_round_trip() {
        for a in Algorithm::all() {
            assert_eq!(Algorithm::parse(a.name()).unwrap(), a);
        }
    }
}
