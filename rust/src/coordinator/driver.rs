//! The training driver: builds the configured algorithm + phi backend,
//! frames the stream, runs the loop with metrics, periodic predictive
//! evaluation and checkpointing, and reports the result.

use super::config::{Algorithm, RunConfig, StoreKind};
use super::metrics::Metrics;
use crate::baselines::{ogs, ovb, rvb, scvb, soi, OnlineLda};
use crate::corpus::Corpus;
use crate::em::foem::Foem;
use crate::em::sem::{Sem, SemConfig};
use crate::eval::{predictive_perplexity, EvalProtocol};
use crate::store::InMemoryPhi;
use crate::stream::{CorpusStream, StreamConfig};
use anyhow::Result;

/// Result of a training run.
pub struct TrainReport {
    pub algorithm: &'static str,
    pub final_perplexity: f64,
    pub metrics: Metrics,
    pub io: Option<crate::store::IoStats>,
}

/// Builds algorithms from config and drives training runs.
pub struct Driver {
    pub cfg: RunConfig,
}

impl Driver {
    pub fn new(cfg: RunConfig) -> Self {
        Self { cfg }
    }

    /// Instantiate the configured algorithm for a corpus of `n_words`
    /// vocabulary and an estimated stream scale `S = D / D_s`.
    pub fn build_algorithm(
        &self,
        n_words: usize,
        scale_s: f64,
    ) -> Result<Box<dyn OnlineLda>> {
        let cfg = &self.cfg;
        let k = cfg.n_topics;
        let params = cfg.params();
        Ok(match cfg.algorithm {
            Algorithm::Foem => match &cfg.store {
                StoreKind::InMemory => Box::new(Foem::new(
                    params,
                    InMemoryPhi::zeros(k, n_words),
                    cfg.foem_config(),
                    cfg.seed,
                )),
                StoreKind::Paged { path, buffer_bytes } => {
                    let mut fc = cfg.foem_config();
                    if fc.hot_words == 0 {
                        // Default hot set: as many columns as half the
                        // buffer holds (phi + residual split).
                        fc.hot_words = (*buffer_bytes / 2 / (k * 4)).max(1);
                    }
                    Box::new(Foem::paged_create(
                        params,
                        path,
                        n_words,
                        *buffer_bytes,
                        fc,
                        cfg.seed,
                    )?)
                }
            },
            Algorithm::Sem => {
                let mut sc = SemConfig::paper(scale_s);
                sc.rate = cfg.rate();
                sc.n_workers = cfg.n_workers;
                Box::new(Sem::new(params, n_words, sc, cfg.seed))
            }
            Algorithm::Scvb => {
                let mut sc = scvb::ScvbConfig::paper(scale_s);
                sc.rate = cfg.rate();
                Box::new(scvb::Scvb::new(k, n_words, sc, cfg.seed))
            }
            Algorithm::Ovb => {
                let mut oc = ovb::OvbConfig::paper(scale_s);
                oc.rate = cfg.rate();
                Box::new(ovb::Ovb::new(k, n_words, oc, cfg.seed))
            }
            Algorithm::Ogs => {
                let mut oc = ogs::OgsConfig::paper(scale_s);
                oc.rate = cfg.rate();
                Box::new(ogs::Ogs::new(k, n_words, oc, cfg.seed))
            }
            Algorithm::Rvb => {
                let mut rc = rvb::RvbConfig::paper(scale_s);
                rc.ovb.rate = cfg.rate();
                Box::new(rvb::Rvb::new(k, n_words, rc, cfg.seed))
            }
            Algorithm::Soi => {
                let mut sc = soi::SoiConfig::paper(scale_s);
                sc.rate = cfg.rate();
                Box::new(soi::Soi::new(k, n_words, sc, cfg.seed))
            }
        })
    }

    /// Train on `train`, evaluating on `test` per `eval_every` and at the
    /// end.
    pub fn train(
        &mut self,
        train: &Corpus,
        test: &Corpus,
    ) -> Result<TrainReport> {
        let scfg = StreamConfig {
            minibatch_docs: self.cfg.minibatch_docs,
            shuffle: true,
            seed: self.cfg.seed,
        };
        let per_pass = CorpusStream::new(train, scfg).batches_per_pass();
        let scale_s = per_pass as f64;
        let mut algo = self.build_algorithm(train.n_words(), scale_s)?;
        let mut metrics = Metrics::new();
        let proto = EvalProtocol { fold_in_iters: 30, seed: self.cfg.seed };

        let mut batch_no = 0usize;
        for pass in 0..self.cfg.passes.max(1) {
            let mut pass_cfg = scfg;
            pass_cfg.seed = scfg.seed.wrapping_add(pass as u64);
            for mb in CorpusStream::new(train, pass_cfg) {
                batch_no += 1;
                let report = algo.process_minibatch(&mb);
                let eval = if self.cfg.eval_every > 0
                    && batch_no % self.cfg.eval_every == 0
                {
                    let phi = algo.export_phi();
                    Some(predictive_perplexity(
                        &phi,
                        &algo.eval_params(),
                        &test.docs,
                        &proto,
                    ))
                } else {
                    None
                };
                metrics.record(batch_no, &report, eval);
                if self.cfg.checkpoint_every > 0
                    && batch_no % self.cfg.checkpoint_every == 0
                {
                    algo.checkpoint()?;
                }
                if self.cfg.verbose {
                    println!(
                        "[{}] batch {batch_no}: iters={} ppx={:.1} {:.2}s{}",
                        algo.name(),
                        report.inner_iters,
                        report.train_perplexity(),
                        report.seconds,
                        eval.map(|p| format!(" eval={p:.1}"))
                            .unwrap_or_default()
                    );
                }
            }
        }
        algo.checkpoint()?;
        let phi = algo.export_phi();
        let final_perplexity = predictive_perplexity(
            &phi,
            &algo.eval_params(),
            &test.docs,
            &proto,
        );
        Ok(TrainReport {
            algorithm: algo.name(),
            final_perplexity,
            io: algo.io_stats(),
            metrics,
        })
    }

    /// Convenience: split 10% (≤ 2000 docs) for test and train on the
    /// rest — the lib.rs quickstart entry point.
    pub fn train_corpus(&mut self, corpus: &Corpus) -> Result<TrainReport> {
        let test_docs = (corpus.n_docs() / 10).clamp(1, 2000);
        let (train, test) = corpus.split(test_docs, self.cfg.seed);
        self.train(&train, &test)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};

    fn small_cfg(algorithm: Algorithm) -> RunConfig {
        RunConfig {
            algorithm,
            n_topics: 6,
            minibatch_docs: 64,
            eval_every: 2,
            ..RunConfig::default()
        }
    }

    #[test]
    fn foem_end_to_end_via_driver() {
        let c = generate(&SyntheticConfig::small(), 91);
        let mut d = Driver::new(small_cfg(Algorithm::Foem));
        let report = d.train_corpus(&c).unwrap();
        assert_eq!(report.algorithm, "FOEM");
        assert!(report.final_perplexity > 1.0);
        assert!(report.final_perplexity < c.n_words() as f64);
        assert!(!report.metrics.records.is_empty());
        assert!(!report.metrics.eval_trace().is_empty());
    }

    #[test]
    fn paged_foem_via_driver_checkpoints() {
        let dir = crate::util::TempDir::new("driver");
        let c = generate(&SyntheticConfig::small(), 92);
        let mut cfg = small_cfg(Algorithm::Foem);
        cfg.store = StoreKind::Paged {
            path: dir.path().join("phi.bin"),
            buffer_bytes: 64 << 10,
        };
        cfg.checkpoint_every = 1;
        let mut d = Driver::new(cfg);
        let report = d.train_corpus(&c).unwrap();
        assert!(report.io.is_some());
        assert!(dir.path().join("phi.bin").exists());
        assert!(report.final_perplexity.is_finite());
    }

    #[test]
    fn driver_threads_n_workers_to_parallel_trainers() {
        let c = generate(&SyntheticConfig::small(), 94);
        for algo in [Algorithm::Foem, Algorithm::Sem] {
            let mut cfg = small_cfg(algo);
            cfg.n_workers = 2;
            cfg.eval_every = 0;
            let mut d = Driver::new(cfg);
            let report = d.train_corpus(&c).unwrap();
            assert_eq!(report.algorithm, algo.name());
            assert!(report.final_perplexity.is_finite());
            assert!(report.final_perplexity < c.n_words() as f64);
        }
    }

    #[test]
    fn every_algorithm_builds_and_trains_one_batch() {
        let mut small = SyntheticConfig::small();
        small.n_docs = 80;
        let c = generate(&small, 93);
        for algo in Algorithm::all() {
            let mut cfg = small_cfg(algo);
            cfg.eval_every = 0;
            cfg.n_topics = 4;
            let mut d = Driver::new(cfg);
            let report = d.train_corpus(&c).unwrap();
            assert_eq!(report.algorithm, algo.name());
            assert!(
                report.final_perplexity.is_finite(),
                "{}",
                algo.name()
            );
        }
    }
}
