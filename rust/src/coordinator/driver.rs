//! The training driver: builds the configured algorithm + phi backend,
//! frames the stream, runs the loop with metrics, periodic predictive
//! evaluation and checkpointing, and reports the result.

use super::checkpoint::{self, TrainerCheckpoint};
use super::config::{Algorithm, RunConfig, StoreKind};
use super::drift::{DetectorKind, DriftMonitor, ResponseKind, ShiftEvent};
use super::metrics::Metrics;
use crate::baselines::{ogs, ovb, rvb, scvb, soi, OnlineLda};
use crate::corpus::Corpus;
use crate::em::foem::{Foem, FoemConfig};
use crate::em::sem::{Sem, SemConfig};
use crate::exec::pipeline::{PhasedTrainer, Pipeline};
use crate::serve::ModelRegistry;
use crate::store::InMemoryPhi;
use crate::stream::{CorpusStream, StreamConfig};
use anyhow::Result;
use std::sync::Arc;

/// Result of a training run.
#[derive(Debug)]
pub struct TrainReport {
    pub algorithm: &'static str,
    pub final_perplexity: f64,
    pub metrics: Metrics,
    pub io: Option<crate::store::IoStats>,
}

/// Builds algorithms from config and drives training runs.
pub struct Driver {
    pub cfg: RunConfig,
    /// Attached serving registry ([`crate::serve`]): when set and
    /// `cfg.serve_publish_every > 0`, the run publishes an epoch-tagged
    /// model snapshot every N minibatches (plus once at the end), so a
    /// concurrent [`crate::serve::Server`] answers requests against the
    /// live model while training continues.
    pub registry: Option<Arc<ModelRegistry>>,
}

impl Driver {
    pub fn new(cfg: RunConfig) -> Self {
        Self { cfg, registry: None }
    }

    /// Attach a serving registry (builder style) — see
    /// [`Driver::registry`] and `examples/serve_stream.rs`.
    pub fn with_registry(mut self, registry: Arc<ModelRegistry>) -> Self {
        self.registry = Some(registry);
        self
    }

    /// The publish vocabulary of a serving run: every column, so any
    /// request vocabulary is materialized in the snapshot. `None` when
    /// this run does not publish (no registry / publishing disabled).
    ///
    /// Cost note: a publish is an O(K·W) snapshot copy (for a paged
    /// store, a full sequential column scan), so at big-model W the
    /// §3.2 memory bound does NOT extend to serving publishes — pick a
    /// `serve_publish_every` cadence the copy cost can amortize. A
    /// bounded alternative (hot-vocabulary or lazily materialized
    /// snapshots) is deliberately left to a follow-up; see
    /// `rust/DESIGN.md` §10.
    fn serve_words(&self, n_words: usize) -> Option<Vec<u32>> {
        (self.registry.is_some() && self.cfg.serve_publish_every > 0)
            .then(|| (0..n_words as u32).collect())
    }

    /// Publish the model's current state to the attached registry — one
    /// column-snapshot read (`OnlineLda::eval_view`) per publish, an
    /// atomic swap on the registry side.
    fn publish_snapshot<A: OnlineLda + ?Sized>(
        registry: &ModelRegistry,
        algo: &mut A,
        words: &[u32],
    ) {
        registry.publish(algo.eval_view(words), algo.eval_params());
    }

    /// Error for the one store/algorithm combination that cannot work:
    /// only FOEM streams its parameters, so a paged store under any other
    /// algorithm would silently train in memory behind the user's back.
    fn ensure_store_supported(&self) -> Result<()> {
        if self.cfg.store != StoreKind::InMemory
            && self.cfg.algorithm != Algorithm::Foem
        {
            anyhow::bail!(
                "the paged parameter-streaming store (store_path / buffer_mb) \
                 is only supported by FOEM; {} keeps its topic-word matrix \
                 in memory and would ignore the store setting",
                self.cfg.algorithm.name()
            );
        }
        Ok(())
    }

    /// FOEM config for a paged run: default the hot set to as many
    /// columns as half the buffer holds (phi + residual split).
    fn foem_paged_config(&self, buffer_bytes: usize) -> FoemConfig {
        let mut fc = self.cfg.foem_config();
        if fc.hot_words == 0 {
            fc.hot_words = (buffer_bytes / 2 / (self.cfg.n_topics * 4)).max(1);
        }
        fc
    }

    /// The write-ahead log is armed when asked for explicitly (`--wal`)
    /// or implied by checkpointing (`--checkpoint-dir`).
    fn wal_armed(&self) -> bool {
        self.cfg.wal || self.cfg.checkpoint_dir.is_some()
    }

    /// Validate the drift knob combination before any training starts.
    /// Detector-only runs (response `none`) are pure telemetry and work
    /// everywhere; *responses* mutate the model between batches, which
    /// the pipelined loop cannot tolerate (staged batches would compute
    /// against pre-mutation snapshots), and `grow` additionally needs a
    /// store that can re-stride K.
    fn ensure_drift_supported(&self) -> Result<()> {
        if self.cfg.drift_response == ResponseKind::None {
            return Ok(());
        }
        if self.cfg.drift_detector == DetectorKind::Off {
            anyhow::bail!(
                "drift_response {} needs a detector: set drift_detector \
                 to cusum or window",
                self.cfg.drift_response.name()
            );
        }
        if self.cfg.algorithm != Algorithm::Foem {
            anyhow::bail!(
                "drift responses are only supported by foem ({} has no \
                 adaptive seam); use drift_response none for telemetry",
                self.cfg.algorithm.name()
            );
        }
        if self.cfg.pipeline_depth > 0 {
            anyhow::bail!(
                "drift responses mutate the model mid-stream and require \
                 pipeline_depth 0 (detector-only telemetry is fine under \
                 pipelining)"
            );
        }
        if self.cfg.drift_response == ResponseKind::Grow
            && self.cfg.store != StoreKind::InMemory
        {
            anyhow::bail!(
                "drift_response grow requires the in-memory store: paged \
                 column records pin K at creation"
            );
        }
        Ok(())
    }

    /// Apply the configured response to a confirmed shift. Returns
    /// `true` if the model was mutated (the caller then re-checkpoints
    /// so the mutation is covered by the durability chain).
    fn apply_drift_response<A: OnlineLda + ?Sized>(
        &self,
        algo: &mut A,
        event: ShiftEvent,
    ) -> Result<bool> {
        let applied = match self.cfg.drift_response {
            ResponseKind::None => return Ok(false),
            ResponseKind::DecayReset => {
                algo.reset_decay(super::drift::DECAY_FACTOR)
            }
            ResponseKind::Widen => algo.widen_exploration(),
            ResponseKind::Grow => {
                algo.grow_topics(self.cfg.drift_grow_topics)
            }
        };
        // ensure_drift_supported pre-validated the combination; an
        // algorithm declining here is a coordination bug, not a user
        // error.
        anyhow::ensure!(
            applied,
            "{} declined drift response {} at batch {}",
            algo.name(),
            self.cfg.drift_response.name(),
            event.batch
        );
        if self.cfg.verbose {
            println!(
                "[drift] batch {}: shift {} (score {:.1}) -> response {}",
                event.batch,
                event.direction.name(),
                event.score,
                self.cfg.drift_response.name()
            );
        }
        Ok(true)
    }

    /// Load + validate the checkpoint a `--resume` run continues from.
    /// `Ok(None)` when this run is not resuming.
    fn load_resume_checkpoint(&self) -> Result<Option<TrainerCheckpoint>> {
        if !self.cfg.resume {
            return Ok(None);
        }
        let Some(dir) = &self.cfg.checkpoint_dir else {
            anyhow::bail!("--resume requires --checkpoint-dir");
        };
        if !matches!(
            (&self.cfg.algorithm, &self.cfg.store),
            (Algorithm::Foem, StoreKind::Paged { .. })
        ) {
            anyhow::bail!(
                "--resume is only supported for FOEM with a paged store \
                 (store_path / buffer_mb)"
            );
        }
        let ckpt = checkpoint::load(dir)?.ok_or_else(|| {
            anyhow::anyhow!(
                "--resume: no trainer checkpoint found in {dir:?} \
                 (did the original run ever reach a checkpoint?)"
            )
        })?;
        checkpoint::verify_compatible(&ckpt, &self.cfg)?;
        Ok(Some(ckpt))
    }

    /// Rebuild a crashed paged FOEM run from its trainer checkpoint +
    /// WAL replay. Returns the trainer and the batch cursor the stream
    /// resumes after. Also restores the serving epoch floor so registry
    /// consumers never observe pre-crash epoch regression.
    fn build_resumed_foem(
        &self,
        ckpt: &TrainerCheckpoint,
    ) -> Result<(Foem<crate::store::paged::PagedPhi>, u64)> {
        let StoreKind::Paged { path, buffer_bytes } = &self.cfg.store
        else {
            anyhow::bail!("--resume requires a paged store");
        };
        let fc = self.foem_paged_config(*buffer_bytes);
        let (algo, cursor) = Foem::paged_resume(
            self.cfg.params(),
            path,
            *buffer_bytes,
            fc,
            &ckpt.state,
        )?;
        if let Some(reg) = &self.registry {
            reg.restore_epoch_floor(ckpt.epoch);
        }
        Ok((algo, cursor))
    }

    /// Sharded twin of [`Self::build_resumed_foem`]: reopen every
    /// shard's store pair with its WAL, replay up to the last GLOBALLY
    /// durable batch, and respawn the owner fleet with logs armed. The
    /// checkpoint fingerprint already pinned `n_shards`, so the
    /// on-disk shard layout is the one this config expects.
    fn build_resumed_foem_sharded(
        &self,
        ckpt: &TrainerCheckpoint,
    ) -> Result<(Foem<crate::shard::ShardedPhi>, u64)> {
        let StoreKind::Paged { path, buffer_bytes } = &self.cfg.store
        else {
            anyhow::bail!("--resume requires a paged store");
        };
        let fc = self.foem_paged_config(*buffer_bytes);
        let (algo, cursor) = Foem::sharded_resume(
            self.cfg.params(),
            path,
            self.cfg.n_shards,
            *buffer_bytes,
            fc,
            &ckpt.state,
        )?;
        if let Some(reg) = &self.registry {
            reg.restore_epoch_floor(ckpt.epoch);
        }
        Ok((algo, cursor))
    }

    /// One durability point, shared by both run loops: flush the stores,
    /// snapshot the trainer atomically (when `--checkpoint-dir` is set),
    /// then truncate the WALs — strictly in that order, so a crash
    /// between any two steps loses nothing.
    fn do_checkpoint<A: OnlineLda + ?Sized>(
        &self,
        algo: &mut A,
        batch_cursor: u64,
    ) -> Result<()> {
        algo.checkpoint()?;
        let Some(dir) = &self.cfg.checkpoint_dir else {
            return Ok(());
        };
        let Some(state) = algo.export_resume_state() else {
            // Memory-resident algorithms have nothing to resume from.
            return Ok(());
        };
        let epoch = self
            .registry
            .as_ref()
            .map(|r| r.current_epoch())
            .unwrap_or(0);
        checkpoint::save(
            dir,
            &TrainerCheckpoint {
                fingerprint: checkpoint::config_fingerprint(&self.cfg),
                batch_cursor,
                epoch,
                state,
            },
        )?;
        // Everything the WALs protected is durable elsewhere now.
        algo.truncate_wal()
    }

    /// SEM config derived from the run config — shared by the plain and
    /// pipelined construction paths so they cannot drift.
    fn sem_config(&self, scale_s: f64) -> SemConfig {
        let mut sc = SemConfig::paper(scale_s);
        sc.rate = self.cfg.rate();
        sc.n_workers = self.cfg.n_workers;
        sc.kernel_backend = self.cfg.kernel_backend;
        sc
    }

    /// Instantiate the configured algorithm for a corpus of `n_words`
    /// vocabulary and an estimated stream scale `S = D / D_s`.
    pub fn build_algorithm(
        &self,
        n_words: usize,
        scale_s: f64,
    ) -> Result<Box<dyn OnlineLda>> {
        self.ensure_store_supported()?;
        let cfg = &self.cfg;
        let k = cfg.n_topics;
        let params = cfg.params();
        Ok(match cfg.algorithm {
            Algorithm::Foem => match &cfg.store {
                StoreKind::InMemory => Box::new(Foem::new(
                    params,
                    InMemoryPhi::zeros(k, n_words),
                    cfg.foem_config(),
                    cfg.seed,
                )),
                StoreKind::Paged { path, buffer_bytes }
                    if cfg.n_shards > 0 =>
                {
                    let fc = self.foem_paged_config(*buffer_bytes);
                    let mut f = Foem::sharded_create_with_codec(
                        params,
                        path,
                        cfg.n_shards,
                        n_words,
                        *buffer_bytes,
                        fc,
                        cfg.seed,
                        cfg.phi_codec,
                    )?;
                    if self.wal_armed() {
                        f.enable_wal()?;
                    }
                    Box::new(f)
                }
                StoreKind::Paged { path, buffer_bytes } => {
                    let fc = self.foem_paged_config(*buffer_bytes);
                    let mut f = Foem::paged_create_with_codec(
                        params,
                        path,
                        n_words,
                        *buffer_bytes,
                        fc,
                        cfg.seed,
                        cfg.phi_codec,
                    )?;
                    if self.wal_armed() {
                        f.enable_wal()?;
                    }
                    Box::new(f)
                }
            },
            Algorithm::Sem => Box::new(Sem::new(
                params,
                n_words,
                self.sem_config(scale_s),
                cfg.seed,
            )),
            Algorithm::Scvb => {
                let mut sc = scvb::ScvbConfig::paper(scale_s);
                sc.rate = cfg.rate();
                Box::new(scvb::Scvb::new(k, n_words, sc, cfg.seed))
            }
            Algorithm::Ovb => {
                let mut oc = ovb::OvbConfig::paper(scale_s);
                oc.rate = cfg.rate();
                Box::new(ovb::Ovb::new(k, n_words, oc, cfg.seed))
            }
            Algorithm::Ogs => {
                let mut oc = ogs::OgsConfig::paper(scale_s);
                oc.rate = cfg.rate();
                Box::new(ogs::Ogs::new(k, n_words, oc, cfg.seed))
            }
            Algorithm::Rvb => {
                let mut rc = rvb::RvbConfig::paper(scale_s);
                rc.ovb.rate = cfg.rate();
                Box::new(rvb::Rvb::new(k, n_words, rc, cfg.seed))
            }
            Algorithm::Soi => {
                let mut sc = soi::SoiConfig::paper(scale_s);
                sc.rate = cfg.rate();
                Box::new(soi::Soi::new(k, n_words, sc, cfg.seed))
            }
        })
    }

    /// Train on `train`, evaluating on `test` per `eval_every` and at the
    /// end.
    ///
    /// Periodic and final evaluation go through
    /// [`OnlineLda::eval_view`] — a sparse view over the test vocabulary
    /// — never a full `export_phi` densification, so a paged run keeps
    /// its §3.2 memory bound and the eval reads show up in `IoStats`.
    ///
    /// With `cfg.pipeline_depth >= 1` the run is dispatched to the
    /// software pipeline ([`crate::exec::pipeline`]): FOEM and SEM
    /// stage/compute/apply with prefetch and write-behind overlapped
    /// against compute. `pipeline_depth == 0` is this plain loop,
    /// bit-identical to the pre-pipeline driver.
    pub fn train(
        &mut self,
        train: &Corpus,
        test: &Corpus,
    ) -> Result<TrainReport> {
        self.ensure_drift_supported()?;
        if self.cfg.pipeline_depth > 0 {
            return self.train_pipelined(train, test);
        }
        let scfg = StreamConfig {
            minibatch_docs: self.cfg.minibatch_docs,
            shuffle: true,
            seed: self.cfg.seed,
        };
        let per_pass = CorpusStream::new(train, scfg).batches_per_pass();
        let scale_s = per_pass as f64;
        let resume = self.load_resume_checkpoint()?;
        let mut start_cursor = 0u64;
        let mut algo: Box<dyn OnlineLda> = match &resume {
            Some(ckpt) if self.cfg.n_shards > 0 => {
                let (a, cursor) = self.build_resumed_foem_sharded(ckpt)?;
                start_cursor = cursor;
                Box::new(a)
            }
            Some(ckpt) => {
                let (a, cursor) = self.build_resumed_foem(ckpt)?;
                start_cursor = cursor;
                Box::new(a)
            }
            None => self.build_algorithm(train.n_words(), scale_s)?,
        };
        let mut metrics = Metrics::new();
        // Periodic/final eval runs the fold-in inference engine with the
        // configured subset/workers (`--fold-in-subset`,
        // `--fold-in-workers`), so evaluation cost scales with NNZ·S.
        let proto = self.cfg.eval_protocol();
        let serve_words = self.serve_words(train.n_words());
        // Shift detection over the per-token training LL (off by
        // default: DetectorKind::Off makes observe() a constant-time
        // no-op and the monitor allocates nothing).
        let mut monitor = DriftMonitor::new(self.cfg.monitor_config());

        let mut batch_no = 0usize;
        for pass in 0..self.cfg.passes.max(1) {
            let mut pass_cfg = scfg;
            pass_cfg.seed = scfg.seed.wrapping_add(pass as u64);
            for mb in CorpusStream::new(train, pass_cfg) {
                batch_no += 1;
                // Resume: the stream is regenerated deterministically
                // (same per-pass seeds), so recovered batches are
                // re-enumerated and skipped, not re-trained.
                if (batch_no as u64) <= start_cursor {
                    continue;
                }
                let report = algo.process_minibatch(&mb);
                if let (Some(words), Some(reg)) =
                    (&serve_words, &self.registry)
                {
                    if batch_no % self.cfg.serve_publish_every == 0 {
                        Self::publish_snapshot(reg, algo.as_mut(), words);
                    }
                }
                let eval = if self.cfg.eval_every > 0
                    && batch_no % self.cfg.eval_every == 0
                {
                    Some(algo.eval_perplexity(&test.docs, &proto))
                } else {
                    None
                };
                let shift = monitor
                    .observe(batch_no, report.train_ll / report.tokens.max(1.0));
                if let Some(event) = shift {
                    if let Some(reg) = &self.registry {
                        reg.note_shift(event);
                    }
                    if self.apply_drift_response(algo.as_mut(), event)? {
                        // A response mutated the model between batches:
                        // fold it into the durability chain immediately
                        // (flush + snapshot + WAL truncate) so a crash
                        // never replays pre-response column state.
                        if self.wal_armed() {
                            self.do_checkpoint(
                                algo.as_mut(),
                                batch_no as u64,
                            )?;
                        }
                    }
                }
                metrics.record(batch_no, &report, eval, shift);
                if self.cfg.checkpoint_every > 0
                    && batch_no % self.cfg.checkpoint_every == 0
                {
                    self.do_checkpoint(algo.as_mut(), batch_no as u64)?;
                }
                if self.cfg.verbose {
                    println!(
                        "[{}] batch {batch_no}: iters={} ppx={:.1} {:.2}s{}{}",
                        algo.name(),
                        report.inner_iters,
                        report.train_perplexity(),
                        report.seconds,
                        eval.map(|p| format!(" eval={p:.1}"))
                            .unwrap_or_default(),
                        shift
                            .map(|s| format!(" SHIFT {}", s.direction.name()))
                            .unwrap_or_default()
                    );
                }
            }
        }
        self.do_checkpoint(algo.as_mut(), batch_no as u64)?;
        // Final publish so serving always sees the end-of-run model.
        if let (Some(words), Some(reg)) = (&serve_words, &self.registry) {
            Self::publish_snapshot(reg, algo.as_mut(), words);
        }
        let final_perplexity = algo.eval_perplexity(&test.docs, &proto);
        Ok(TrainReport {
            algorithm: algo.name(),
            final_perplexity,
            io: algo.io_stats(),
            metrics,
        })
    }

    /// Pipelined training (`pipeline_depth >= 1`): build the concrete
    /// three-phase trainer (the pipeline needs the [`PhasedTrainer`]
    /// seam, which only FOEM and SEM implement) and drive it through
    /// [`Pipeline::run`].
    fn train_pipelined(
        &mut self,
        train: &Corpus,
        test: &Corpus,
    ) -> Result<TrainReport> {
        self.ensure_store_supported()?;
        let cfg = self.cfg.clone();
        let k = cfg.n_topics;
        let params = cfg.params();
        let scfg = StreamConfig {
            minibatch_docs: cfg.minibatch_docs,
            shuffle: true,
            seed: cfg.seed,
        };
        let scale_s = CorpusStream::new(train, scfg).batches_per_pass() as f64;
        let resume = self.load_resume_checkpoint()?;
        match (&cfg.algorithm, &cfg.store) {
            (Algorithm::Foem, StoreKind::InMemory) => {
                let algo = Foem::new(
                    params,
                    InMemoryPhi::zeros(k, train.n_words()),
                    cfg.foem_config(),
                    cfg.seed,
                );
                self.run_pipelined(algo, train, test, 0)
            }
            (Algorithm::Foem, StoreKind::Paged { path, buffer_bytes }) => {
                if let Some(ckpt) = &resume {
                    if cfg.n_shards > 0 {
                        let (algo, cursor) =
                            self.build_resumed_foem_sharded(ckpt)?;
                        return self.run_pipelined(algo, train, test, cursor);
                    }
                    let (algo, cursor) = self.build_resumed_foem(ckpt)?;
                    return self.run_pipelined(algo, train, test, cursor);
                }
                if cfg.n_shards > 0 {
                    let fc = self.foem_paged_config(*buffer_bytes);
                    let mut algo = Foem::sharded_create_with_codec(
                        params,
                        path,
                        cfg.n_shards,
                        train.n_words(),
                        *buffer_bytes,
                        fc,
                        cfg.seed,
                        cfg.phi_codec,
                    )?;
                    if self.wal_armed() {
                        algo.enable_wal()?;
                    }
                    return self.run_pipelined(algo, train, test, 0);
                }
                let fc = self.foem_paged_config(*buffer_bytes);
                let mut algo = Foem::paged_create_with_codec(
                    params,
                    path,
                    train.n_words(),
                    *buffer_bytes,
                    fc,
                    cfg.seed,
                    cfg.phi_codec,
                )?;
                if self.wal_armed() {
                    algo.enable_wal()?;
                }
                self.run_pipelined(algo, train, test, 0)
            }
            (Algorithm::Sem, _) => {
                let sc = self.sem_config(scale_s);
                let algo = Sem::new(params, train.n_words(), sc, cfg.seed);
                self.run_pipelined(algo, train, test, 0)
            }
            (other, _) => anyhow::bail!(
                "pipeline_depth > 0 requires a three-phase trainer \
                 (foem or sem), got {}",
                other.name()
            ),
        }
    }

    /// The pipelined run loop shared by every three-phase trainer: the
    /// same metrics / eval / checkpoint cadence as the plain loop, hooked
    /// into the pipeline's strict-batch-order sink.
    fn run_pipelined<T>(
        &self,
        mut algo: T,
        train: &Corpus,
        test: &Corpus,
        start_cursor: u64,
    ) -> Result<TrainReport>
    where
        T: PhasedTrainer + OnlineLda,
    {
        let cfg = &self.cfg;
        let scfg = StreamConfig {
            minibatch_docs: cfg.minibatch_docs,
            shuffle: true,
            seed: cfg.seed,
        };
        let mut metrics = Metrics::new();
        let proto = cfg.eval_protocol();
        let serve_words = self.serve_words(train.n_words());
        let registry = &self.registry;
        // Detector-only under pipelining (responses are rejected by
        // ensure_drift_supported): alarms flow to telemetry, never back
        // into the model, so staged batches stay coherent.
        let mut monitor = DriftMonitor::new(cfg.monitor_config());
        let passes = cfg.passes.max(1);
        // Resume: regenerate the deterministic multi-pass stream and
        // skip the batches the recovered state already covers; every
        // cadence below runs on the GLOBAL batch number so eval/
        // checkpoint/publish stay aligned with the original run.
        let stream = (0..passes)
            .flat_map(|pass| {
                let mut pass_cfg = scfg;
                pass_cfg.seed = scfg.seed.wrapping_add(pass as u64);
                CorpusStream::new(train, pass_cfg)
            })
            .skip(start_cursor as usize);
        let mut last_gb = start_cursor;
        Pipeline::new(cfg.pipeline_depth).run(
            &mut algo,
            stream,
            |algo, batch_no, report| {
                let gb = start_cursor as usize + batch_no;
                last_gb = gb as u64;
                if let (Some(words), Some(reg)) = (&serve_words, registry) {
                    if gb % cfg.serve_publish_every == 0 {
                        Self::publish_snapshot(reg, algo, words);
                    }
                }
                let eval = if cfg.eval_every > 0
                    && gb % cfg.eval_every == 0
                {
                    Some(algo.eval_perplexity(&test.docs, &proto))
                } else {
                    None
                };
                let shift =
                    monitor.observe(gb, report.train_ll / report.tokens.max(1.0));
                if let (Some(event), Some(reg)) = (shift, registry) {
                    reg.note_shift(event);
                }
                metrics.record(gb, report, eval, shift);
                if cfg.checkpoint_every > 0
                    && gb % cfg.checkpoint_every == 0
                {
                    self.do_checkpoint(algo, gb as u64)?;
                }
                if cfg.verbose {
                    println!(
                        "[{}] batch {gb}: iters={} ppx={:.1} {:.2}s{}",
                        algo.name(),
                        report.inner_iters,
                        report.train_perplexity(),
                        report.seconds,
                        eval.map(|p| format!(" eval={p:.1}"))
                            .unwrap_or_default()
                    );
                }
                Ok(())
            },
        )?;
        self.do_checkpoint(&mut algo, last_gb)?;
        // Final publish so serving always sees the end-of-run model.
        if let (Some(words), Some(reg)) = (&serve_words, registry) {
            Self::publish_snapshot(reg, &mut algo, words);
        }
        let final_perplexity = algo.eval_perplexity(&test.docs, &proto);
        Ok(TrainReport {
            algorithm: algo.name(),
            final_perplexity,
            io: algo.io_stats(),
            metrics,
        })
    }

    /// Resume a crashed/killed run from `cfg.checkpoint_dir`: restore
    /// the atomic trainer snapshot, replay WAL-committed batches, skip
    /// the recovered prefix of the deterministic stream, and continue —
    /// bit-identical to the run that never crashed. Equivalent to
    /// [`Driver::train`] with `cfg.resume` forced on; the checkpoint
    /// must exist and the config must fingerprint-match it.
    pub fn resume(
        &mut self,
        train: &Corpus,
        test: &Corpus,
    ) -> Result<TrainReport> {
        self.cfg.resume = true;
        self.train(train, test)
    }

    /// Convenience: split 10% (≤ 2000 docs) for test and train on the
    /// rest — the lib.rs quickstart entry point.
    pub fn train_corpus(&mut self, corpus: &Corpus) -> Result<TrainReport> {
        let test_docs = (corpus.n_docs() / 10).clamp(1, 2000);
        let (train, test) = corpus.split(test_docs, self.cfg.seed);
        self.train(&train, &test)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::synthetic::{generate, SyntheticConfig};

    fn small_cfg(algorithm: Algorithm) -> RunConfig {
        RunConfig {
            algorithm,
            n_topics: 6,
            minibatch_docs: 64,
            eval_every: 2,
            ..RunConfig::default()
        }
    }

    #[test]
    fn foem_end_to_end_via_driver() {
        let c = generate(&SyntheticConfig::small(), 91);
        let mut d = Driver::new(small_cfg(Algorithm::Foem));
        let report = d.train_corpus(&c).unwrap();
        assert_eq!(report.algorithm, "FOEM");
        assert!(report.final_perplexity > 1.0);
        assert!(report.final_perplexity < c.n_words() as f64);
        assert!(!report.metrics.records.is_empty());
        assert!(!report.metrics.eval_trace().is_empty());
    }

    #[test]
    fn paged_foem_via_driver_checkpoints() {
        let dir = crate::util::TempDir::new("driver");
        let c = generate(&SyntheticConfig::small(), 92);
        let mut cfg = small_cfg(Algorithm::Foem);
        cfg.store = StoreKind::Paged {
            path: dir.path().join("phi.bin"),
            buffer_bytes: 64 << 10,
        };
        cfg.checkpoint_every = 1;
        let mut d = Driver::new(cfg);
        let report = d.train_corpus(&c).unwrap();
        assert!(report.io.is_some());
        assert!(dir.path().join("phi.bin").exists());
        assert!(report.final_perplexity.is_finite());
    }

    #[test]
    fn driver_eval_uses_scheduled_parallel_fold_in() {
        // The fold-in knobs must reach the evaluator: a run with a
        // scheduled subset + 2 eval workers produces a sane eval trace.
        let c = generate(&SyntheticConfig::small(), 99);
        let mut cfg = small_cfg(Algorithm::Foem);
        cfg.n_topics = 24;
        cfg.fold_in_subset = 8;
        cfg.fold_in_workers = 2;
        let mut d = Driver::new(cfg);
        let report = d.train_corpus(&c).unwrap();
        assert!(!report.metrics.eval_trace().is_empty());
        assert!(report.final_perplexity > 1.0);
        assert!(report.final_perplexity < c.n_words() as f64);
    }

    #[test]
    fn driver_threads_n_workers_to_parallel_trainers() {
        let c = generate(&SyntheticConfig::small(), 94);
        for algo in [Algorithm::Foem, Algorithm::Sem] {
            let mut cfg = small_cfg(algo);
            cfg.n_workers = 2;
            cfg.eval_every = 0;
            let mut d = Driver::new(cfg);
            let report = d.train_corpus(&c).unwrap();
            assert_eq!(report.algorithm, algo.name());
            assert!(report.final_perplexity.is_finite());
            assert!(report.final_perplexity < c.n_words() as f64);
        }
    }

    #[test]
    fn paged_store_rejected_for_non_foem_algorithms() {
        // Satellite fix: StoreKind::Paged used to be silently dropped for
        // every algorithm but FOEM — now it is a hard error.
        let dir = crate::util::TempDir::new("reject");
        let c = generate(&SyntheticConfig::small(), 95);
        for algo in Algorithm::all() {
            let mut cfg = small_cfg(algo);
            cfg.store = StoreKind::Paged {
                path: dir.path().join("phi.bin"),
                buffer_bytes: 64 << 10,
            };
            let mut d = Driver::new(cfg);
            let result = d.train_corpus(&c);
            if algo == Algorithm::Foem {
                assert!(result.is_ok(), "FOEM must accept the paged store");
            } else {
                let err = result.expect_err(algo.name()).to_string();
                assert!(
                    err.contains("only supported by FOEM"),
                    "{}: {err}",
                    algo.name()
                );
            }
        }
    }

    #[test]
    fn pipelined_driver_trains_foem_paged() {
        let dir = crate::util::TempDir::new("pipe");
        let c = generate(&SyntheticConfig::small(), 96);
        let mut cfg = small_cfg(Algorithm::Foem);
        cfg.store = StoreKind::Paged {
            path: dir.path().join("phi.bin"),
            buffer_bytes: 64 << 10,
        };
        cfg.pipeline_depth = 2;
        cfg.n_workers = 2;
        cfg.checkpoint_every = 2;
        let mut d = Driver::new(cfg);
        let report = d.train_corpus(&c).unwrap();
        assert_eq!(report.algorithm, "FOEM");
        assert!(report.final_perplexity.is_finite());
        assert!(report.final_perplexity < c.n_words() as f64);
        assert!(!report.metrics.eval_trace().is_empty());
        let io = report.io.expect("paged run reports I/O");
        assert!(io.prefetched_cols > 0, "prefetcher never ran: {io:?}");
    }

    #[test]
    fn pipelined_driver_trains_sem_in_memory() {
        let c = generate(&SyntheticConfig::small(), 97);
        let mut cfg = small_cfg(Algorithm::Sem);
        cfg.pipeline_depth = 1;
        cfg.eval_every = 0;
        let mut d = Driver::new(cfg);
        let report = d.train_corpus(&c).unwrap();
        assert_eq!(report.algorithm, "SEM");
        assert!(report.final_perplexity.is_finite());
        assert!(report.final_perplexity < c.n_words() as f64);
    }

    #[test]
    fn pipeline_rejects_non_phased_algorithms() {
        let c = generate(&SyntheticConfig::small(), 98);
        let mut cfg = small_cfg(Algorithm::Ovb);
        cfg.pipeline_depth = 2;
        let mut d = Driver::new(cfg);
        let err = d.train_corpus(&c).expect_err("OVB has no phase seam");
        assert!(err.to_string().contains("three-phase"), "{err}");
    }

    #[test]
    fn driver_publishes_serving_snapshots() {
        use crate::em::PhiAccess;
        let c = generate(&SyntheticConfig::small(), 101);
        let mut cfg = small_cfg(Algorithm::Foem);
        cfg.eval_every = 0;
        cfg.serve_publish_every = 2;
        let registry = Arc::new(ModelRegistry::new());
        let mut d = Driver::new(cfg).with_registry(Arc::clone(&registry));
        d.train_corpus(&c).unwrap();
        // At least one periodic publish plus the final one.
        assert!(registry.current_epoch() >= 2, "{}", registry.current_epoch());
        let snap = registry.latest().unwrap();
        assert_eq!(snap.k(), 6);
        // The publish vocabulary is the FULL vocabulary, so any request
        // is materialized in the snapshot.
        assert_eq!(snap.view().n_columns(), c.n_words());
        assert!(snap.phisum().iter().any(|&x| x > 0.0));
    }

    #[test]
    fn pipelined_driver_publishes_serving_snapshots() {
        let c = generate(&SyntheticConfig::small(), 102);
        let mut cfg = small_cfg(Algorithm::Sem);
        cfg.eval_every = 0;
        cfg.pipeline_depth = 1;
        cfg.serve_publish_every = 1;
        let registry = Arc::new(ModelRegistry::new());
        let mut d = Driver::new(cfg).with_registry(Arc::clone(&registry));
        d.train_corpus(&c).unwrap();
        assert!(registry.current_epoch() >= 2, "{}", registry.current_epoch());
    }

    #[test]
    fn attached_registry_without_publish_knob_stays_silent() {
        let c = generate(&SyntheticConfig::small(), 103);
        let mut cfg = small_cfg(Algorithm::Foem);
        cfg.eval_every = 0;
        // serve_publish_every stays at its default of 0.
        let registry = Arc::new(ModelRegistry::new());
        let mut d = Driver::new(cfg).with_registry(Arc::clone(&registry));
        d.train_corpus(&c).unwrap();
        assert_eq!(registry.current_epoch(), 0);
    }

    #[test]
    fn recovery_driver_resume_completed_run_is_noop_and_bit_identical() {
        // Resuming a run that finished must retrain nothing, keep the
        // serving epoch floor, and land on the bit-identical model.
        let dir = crate::util::TempDir::new("resume-noop");
        let c = generate(&SyntheticConfig::small(), 104);
        let mut cfg = small_cfg(Algorithm::Foem);
        cfg.store = StoreKind::Paged {
            path: dir.path().join("phi.bin"),
            buffer_bytes: 64 << 10,
        };
        cfg.checkpoint_dir = Some(dir.path().join("ckpt"));
        cfg.checkpoint_every = 2;
        cfg.serve_publish_every = 2;
        let reg1 = Arc::new(ModelRegistry::new());
        let mut d = Driver::new(cfg.clone()).with_registry(Arc::clone(&reg1));
        let r1 = d.train_corpus(&c).unwrap();

        let reg2 = Arc::new(ModelRegistry::new());
        let mut d2 =
            Driver::new(cfg).with_registry(Arc::clone(&reg2));
        d2.cfg.resume = true;
        let r2 = d2.train_corpus(&c).unwrap();
        assert!(
            r2.metrics.records.is_empty(),
            "a completed run must not retrain any batch"
        );
        assert_eq!(
            r1.final_perplexity.to_bits(),
            r2.final_perplexity.to_bits(),
            "{} vs {}",
            r1.final_perplexity,
            r2.final_perplexity
        );
        // Epoch floor: the fresh registry resumes at the recovered epoch
        // and the final publish moves it forward, never backward.
        assert_eq!(reg2.current_epoch(), reg1.current_epoch());
    }

    #[test]
    fn recovery_pipelined_driver_resume_is_noop_too() {
        let dir = crate::util::TempDir::new("resume-pipe");
        let c = generate(&SyntheticConfig::small(), 105);
        let mut cfg = small_cfg(Algorithm::Foem);
        cfg.eval_every = 0;
        cfg.store = StoreKind::Paged {
            path: dir.path().join("phi.bin"),
            buffer_bytes: 64 << 10,
        };
        cfg.checkpoint_dir = Some(dir.path().join("ckpt"));
        cfg.checkpoint_every = 3;
        cfg.pipeline_depth = 2;
        cfg.n_workers = 2;
        let mut d = Driver::new(cfg.clone());
        let r1 = d.train_corpus(&c).unwrap();
        let mut d2 = Driver::new(cfg);
        d2.cfg.resume = true;
        let r2 = d2.train_corpus(&c).unwrap();
        assert!(r2.metrics.records.is_empty());
        assert_eq!(
            r1.final_perplexity.to_bits(),
            r2.final_perplexity.to_bits()
        );
    }

    #[test]
    fn recovery_driver_resume_rejects_changed_config() {
        let dir = crate::util::TempDir::new("resume-fp");
        let c = generate(&SyntheticConfig::small(), 106);
        let mut cfg = small_cfg(Algorithm::Foem);
        cfg.eval_every = 0;
        cfg.store = StoreKind::Paged {
            path: dir.path().join("phi.bin"),
            buffer_bytes: 64 << 10,
        };
        cfg.checkpoint_dir = Some(dir.path().join("ckpt"));
        cfg.checkpoint_every = 2;
        Driver::new(cfg.clone()).train_corpus(&c).unwrap();
        // A numerics-affecting knob changed since the checkpoint: hard
        // error, never a silently-diverging resume.
        cfg.seed = 7;
        cfg.resume = true;
        let err = Driver::new(cfg).train_corpus(&c).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
    }

    #[test]
    fn recovery_driver_resume_preconditions_are_checked() {
        let c = generate(&SyntheticConfig::small(), 107);
        // No checkpoint dir at all.
        let mut cfg = small_cfg(Algorithm::Foem);
        cfg.eval_every = 0;
        cfg.resume = true;
        let err = Driver::new(cfg).train_corpus(&c).unwrap_err();
        assert!(err.to_string().contains("checkpoint-dir"), "{err}");
        // In-memory store cannot resume.
        let dir = crate::util::TempDir::new("resume-pre");
        let mut cfg = small_cfg(Algorithm::Foem);
        cfg.eval_every = 0;
        cfg.resume = true;
        cfg.checkpoint_dir = Some(dir.path().join("ckpt"));
        let err = Driver::new(cfg).train_corpus(&c).unwrap_err();
        assert!(err.to_string().contains("paged store"), "{err}");
        // Paged, but the checkpoint was never written.
        let mut cfg = small_cfg(Algorithm::Foem);
        cfg.eval_every = 0;
        cfg.resume = true;
        cfg.store = StoreKind::Paged {
            path: dir.path().join("phi.bin"),
            buffer_bytes: 64 << 10,
        };
        cfg.checkpoint_dir = Some(dir.path().join("ckpt"));
        let err = Driver::new(cfg).train_corpus(&c).unwrap_err();
        assert!(err.to_string().contains("no trainer checkpoint"), "{err}");
    }

    #[test]
    fn recovery_wal_on_run_matches_wal_off_bitwise() {
        // Acceptance criterion: arming the WAL must not change a single
        // bit of the training result (it only adds a log).
        let c = generate(&SyntheticConfig::small(), 108);
        let run = |wal: bool, dir: &crate::util::TempDir| {
            let mut cfg = small_cfg(Algorithm::Foem);
            cfg.eval_every = 0;
            cfg.store = StoreKind::Paged {
                path: dir.path().join("phi.bin"),
                buffer_bytes: 64 << 10,
            };
            cfg.wal = wal;
            Driver::new(cfg).train_corpus(&c).unwrap().final_perplexity
        };
        let d_off = crate::util::TempDir::new("wal-off");
        let d_on = crate::util::TempDir::new("wal-on");
        let off = run(false, &d_off);
        let on = run(true, &d_on);
        assert_eq!(off.to_bits(), on.to_bits(), "{off} vs {on}");
        assert!(
            !d_off.path().join("phi.bin.wal").exists(),
            "wal-off run must leave no WAL artifacts"
        );
        assert!(d_on.path().join("phi.bin.wal").exists());
    }

    #[test]
    fn every_algorithm_builds_and_trains_one_batch() {
        let mut small = SyntheticConfig::small();
        small.n_docs = 80;
        let c = generate(&small, 93);
        for algo in Algorithm::all() {
            let mut cfg = small_cfg(algo);
            cfg.eval_every = 0;
            cfg.n_topics = 4;
            let mut d = Driver::new(cfg);
            let report = d.train_corpus(&c).unwrap();
            assert_eq!(report.algorithm, algo.name());
            assert!(
                report.final_perplexity.is_finite(),
                "{}",
                algo.name()
            );
        }
    }
}
