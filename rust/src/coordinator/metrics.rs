//! Run metrics: per-minibatch records and aggregate throughput, consumed
//! by the experiment harness (`expfig`) and printed by `foem train`.

use crate::em::MinibatchReport;

/// One record per processed minibatch.
#[derive(Debug, Clone, Copy)]
pub struct BatchRecord {
    pub index: usize,
    pub inner_iters: usize,
    pub seconds: f64,
    pub tokens: f64,
    pub train_perplexity: f64,
    /// Cumulative wall-clock at the end of this minibatch.
    pub elapsed: f64,
    /// Predictive perplexity if an eval fired after this minibatch.
    pub eval_perplexity: Option<f64>,
    /// Responsibility-arena bytes of this minibatch (O(NNZ·S) working
    /// set, summed over concurrent shard workers).
    pub resp_bytes: usize,
    /// Auxiliary per-minibatch scratch bytes.
    pub scratch_bytes: usize,
}

/// Aggregated run metrics.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub records: Vec<BatchRecord>,
    pub total_tokens: f64,
    pub total_seconds: f64,
    /// Largest per-minibatch responsibility working set seen in the run.
    pub peak_resp_bytes: usize,
    /// Largest per-minibatch auxiliary scratch seen in the run.
    pub peak_scratch_bytes: usize,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(
        &mut self,
        index: usize,
        report: &MinibatchReport,
        eval_perplexity: Option<f64>,
    ) {
        self.total_tokens += report.tokens;
        self.total_seconds += report.seconds;
        self.peak_resp_bytes = self.peak_resp_bytes.max(report.resp_bytes);
        self.peak_scratch_bytes =
            self.peak_scratch_bytes.max(report.scratch_bytes);
        self.records.push(BatchRecord {
            index,
            inner_iters: report.inner_iters,
            seconds: report.seconds,
            tokens: report.tokens,
            train_perplexity: report.train_perplexity(),
            elapsed: self.total_seconds,
            eval_perplexity,
            resp_bytes: report.resp_bytes,
            scratch_bytes: report.scratch_bytes,
        });
    }

    /// Mean training throughput in tokens/second.
    pub fn tokens_per_second(&self) -> f64 {
        if self.total_seconds > 0.0 {
            self.total_tokens / self.total_seconds
        } else {
            0.0
        }
    }

    /// The trace of `(elapsed seconds, predictive perplexity)` points —
    /// the Fig. 12 series.
    pub fn eval_trace(&self) -> Vec<(f64, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.eval_perplexity.map(|p| (r.elapsed, p)))
            .collect()
    }

    /// Mean inner iterations per minibatch.
    pub fn mean_inner_iters(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.inner_iters as f64).sum::<f64>()
            / self.records.len() as f64
    }

    /// CSV dump (header + rows) for external plotting.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "batch,inner_iters,seconds,tokens,train_ppx,elapsed,eval_ppx,\
             resp_bytes,scratch_bytes\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{:.6},{},{:.3},{:.6},{},{},{}\n",
                r.index,
                r.inner_iters,
                r.seconds,
                r.tokens,
                r.train_perplexity,
                r.elapsed,
                r.eval_perplexity
                    .map(|p| format!("{p:.3}"))
                    .unwrap_or_default(),
                r.resp_bytes,
                r.scratch_bytes,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(seconds: f64, tokens: f64) -> MinibatchReport {
        MinibatchReport {
            inner_iters: 3,
            seconds,
            train_ll: -tokens,
            tokens,
            resp_bytes: tokens as usize,
            scratch_bytes: 2 * tokens as usize,
        }
    }

    #[test]
    fn aggregates_accumulate() {
        let mut m = Metrics::new();
        m.record(1, &report(0.5, 100.0), None);
        m.record(2, &report(0.5, 300.0), Some(42.0));
        assert_eq!(m.records.len(), 2);
        assert!((m.total_tokens - 400.0).abs() < 1e-9);
        assert!((m.tokens_per_second() - 400.0).abs() < 1e-6);
        assert!((m.mean_inner_iters() - 3.0).abs() < 1e-9);
        assert_eq!(m.peak_resp_bytes, 300);
        assert_eq!(m.peak_scratch_bytes, 600);
    }

    #[test]
    fn eval_trace_collects_only_evals() {
        let mut m = Metrics::new();
        m.record(1, &report(1.0, 10.0), None);
        m.record(2, &report(1.0, 10.0), Some(99.0));
        m.record(3, &report(1.0, 10.0), Some(90.0));
        let tr = m.eval_trace();
        assert_eq!(tr.len(), 2);
        assert!((tr[0].0 - 2.0).abs() < 1e-9);
        assert_eq!(tr[1].1, 90.0);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut m = Metrics::new();
        m.record(1, &report(1.0, 10.0), Some(5.0));
        let csv = m.to_csv();
        assert!(csv.starts_with("batch,"));
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("5.000"));
    }
}
