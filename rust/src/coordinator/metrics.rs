//! Run metrics: per-minibatch records and aggregate throughput, consumed
//! by the experiment harness (`expfig`) and printed by `foem train`.

use crate::coordinator::drift::{ShiftDirection, ShiftEvent};
use crate::em::MinibatchReport;

/// One record per processed minibatch.
#[derive(Debug, Clone, Copy)]
pub struct BatchRecord {
    pub index: usize,
    pub inner_iters: usize,
    pub seconds: f64,
    pub tokens: f64,
    pub train_perplexity: f64,
    /// Cumulative wall-clock at the end of this minibatch.
    pub elapsed: f64,
    /// Predictive perplexity if an eval fired after this minibatch.
    pub eval_perplexity: Option<f64>,
    /// Responsibility-arena bytes of this minibatch (O(NNZ·S) working
    /// set, summed over concurrent shard workers).
    pub resp_bytes: usize,
    /// Auxiliary per-minibatch scratch bytes.
    pub scratch_bytes: usize,
    /// Shift alarm raised by the drift monitor after this minibatch
    /// ([`crate::coordinator::drift`]), if any.
    pub shift: Option<ShiftEvent>,
}

/// Aggregated run metrics.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub records: Vec<BatchRecord>,
    pub total_tokens: f64,
    pub total_seconds: f64,
    /// Largest per-minibatch responsibility working set seen in the run.
    pub peak_resp_bytes: usize,
    /// Largest per-minibatch auxiliary scratch seen in the run.
    pub peak_scratch_bytes: usize,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(
        &mut self,
        index: usize,
        report: &MinibatchReport,
        eval_perplexity: Option<f64>,
        shift: Option<ShiftEvent>,
    ) {
        self.total_tokens += report.tokens;
        self.total_seconds += report.seconds;
        self.peak_resp_bytes = self.peak_resp_bytes.max(report.resp_bytes);
        self.peak_scratch_bytes =
            self.peak_scratch_bytes.max(report.scratch_bytes);
        self.records.push(BatchRecord {
            index,
            inner_iters: report.inner_iters,
            seconds: report.seconds,
            tokens: report.tokens,
            train_perplexity: report.train_perplexity(),
            elapsed: self.total_seconds,
            eval_perplexity,
            resp_bytes: report.resp_bytes,
            scratch_bytes: report.scratch_bytes,
            shift,
        });
    }

    /// Mean training throughput in tokens/second.
    pub fn tokens_per_second(&self) -> f64 {
        if self.total_seconds > 0.0 {
            self.total_tokens / self.total_seconds
        } else {
            0.0
        }
    }

    /// The trace of `(elapsed seconds, predictive perplexity)` points —
    /// the Fig. 12 series.
    pub fn eval_trace(&self) -> Vec<(f64, f64)> {
        self.records
            .iter()
            .filter_map(|r| r.eval_perplexity.map(|p| (r.elapsed, p)))
            .collect()
    }

    /// Every shift alarm recorded in the run, in batch order.
    pub fn shift_events(&self) -> Vec<ShiftEvent> {
        self.records.iter().filter_map(|r| r.shift).collect()
    }

    /// Mean inner iterations per minibatch.
    pub fn mean_inner_iters(&self) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().map(|r| r.inner_iters as f64).sum::<f64>()
            / self.records.len() as f64
    }

    /// CSV dump (header + rows) for external plotting.
    ///
    /// Columns are append-only: new telemetry lands at the END of the
    /// row so consumers that index the header (or tolerate trailing
    /// columns, like [`Metrics::parse_csv`]) keep working across
    /// versions. `csv_round_trips_and_tolerates_extra_columns` pins
    /// this contract.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "batch,inner_iters,seconds,tokens,train_ppx,elapsed,eval_ppx,\
             resp_bytes,scratch_bytes,shift_dir,shift_score\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{:.6},{},{:.3},{:.6},{},{},{},{},{}\n",
                r.index,
                r.inner_iters,
                r.seconds,
                r.tokens,
                r.train_perplexity,
                r.elapsed,
                r.eval_perplexity
                    .map(|p| format!("{p:.3}"))
                    .unwrap_or_default(),
                r.resp_bytes,
                r.scratch_bytes,
                r.shift.map(|s| s.direction.name()).unwrap_or_default(),
                r.shift
                    .map(|s| format!("{:.3}", s.score))
                    .unwrap_or_default(),
            ));
        }
        out
    }

    /// Parse a [`Metrics::to_csv`] dump back into records.
    ///
    /// Header-indexed: columns are located by name, unknown columns are
    /// ignored, and optional columns (eval_ppx, the shift pair) may be
    /// absent entirely — so consumers built against an older or newer
    /// column set both parse. Aggregates (totals, peaks) are rebuilt
    /// from the rows.
    pub fn parse_csv(text: &str) -> anyhow::Result<Metrics> {
        let mut lines = text.lines();
        let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty CSV"))?;
        let cols: Vec<&str> = header.split(',').map(str::trim).collect();
        let col = |name: &str| cols.iter().position(|&c| c == name);
        let need = |name: &str| {
            col(name).ok_or_else(|| anyhow::anyhow!("CSV missing column {name}"))
        };
        let c_batch = need("batch")?;
        let c_inner = need("inner_iters")?;
        let c_seconds = need("seconds")?;
        let c_tokens = need("tokens")?;
        let c_train = need("train_ppx")?;
        let c_elapsed = need("elapsed")?;
        let c_eval = col("eval_ppx");
        let c_resp = col("resp_bytes");
        let c_scratch = col("scratch_bytes");
        let c_dir = col("shift_dir");
        let c_score = col("shift_score");

        let mut m = Metrics::new();
        for (ln, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split(',').map(str::trim).collect();
            let get = |i: usize| -> anyhow::Result<&str> {
                f.get(i).copied().ok_or_else(|| {
                    anyhow::anyhow!("row {}: missing column {i}", ln + 2)
                })
            };
            // Optional columns may be absent (shorter rows from an older
            // writer) or empty (this writer's None encoding).
            let opt = |i: Option<usize>| -> Option<&str> {
                i.and_then(|i| f.get(i)).copied().filter(|s| !s.is_empty())
            };
            let shift = match (opt(c_dir), opt(c_score)) {
                (Some(d), Some(s)) => Some(ShiftEvent {
                    batch: get(c_batch)?.parse()?,
                    direction: match d {
                        "up" => ShiftDirection::Up,
                        "down" => ShiftDirection::Down,
                        other => anyhow::bail!(
                            "row {}: bad shift_dir {other:?}",
                            ln + 2
                        ),
                    },
                    score: s.parse()?,
                }),
                _ => None,
            };
            let rec = BatchRecord {
                index: get(c_batch)?.parse()?,
                inner_iters: get(c_inner)?.parse()?,
                seconds: get(c_seconds)?.parse()?,
                tokens: get(c_tokens)?.parse()?,
                train_perplexity: get(c_train)?.parse()?,
                elapsed: get(c_elapsed)?.parse()?,
                eval_perplexity: opt(c_eval).map(str::parse).transpose()?,
                resp_bytes: opt(c_resp).map(str::parse).transpose()?.unwrap_or(0),
                scratch_bytes: opt(c_scratch)
                    .map(str::parse)
                    .transpose()?
                    .unwrap_or(0),
                shift,
            };
            m.total_tokens += rec.tokens;
            m.total_seconds += rec.seconds;
            m.peak_resp_bytes = m.peak_resp_bytes.max(rec.resp_bytes);
            m.peak_scratch_bytes = m.peak_scratch_bytes.max(rec.scratch_bytes);
            m.records.push(rec);
        }
        Ok(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(seconds: f64, tokens: f64) -> MinibatchReport {
        MinibatchReport {
            inner_iters: 3,
            seconds,
            train_ll: -tokens,
            tokens,
            resp_bytes: tokens as usize,
            scratch_bytes: 2 * tokens as usize,
        }
    }

    #[test]
    fn aggregates_accumulate() {
        let mut m = Metrics::new();
        m.record(1, &report(0.5, 100.0), None, None);
        m.record(2, &report(0.5, 300.0), Some(42.0), None);
        assert_eq!(m.records.len(), 2);
        assert!((m.total_tokens - 400.0).abs() < 1e-9);
        assert!((m.tokens_per_second() - 400.0).abs() < 1e-6);
        assert!((m.mean_inner_iters() - 3.0).abs() < 1e-9);
        assert_eq!(m.peak_resp_bytes, 300);
        assert_eq!(m.peak_scratch_bytes, 600);
    }

    #[test]
    fn eval_trace_collects_only_evals() {
        let mut m = Metrics::new();
        m.record(1, &report(1.0, 10.0), None, None);
        m.record(2, &report(1.0, 10.0), Some(99.0), None);
        m.record(3, &report(1.0, 10.0), Some(90.0), None);
        let tr = m.eval_trace();
        assert_eq!(tr.len(), 2);
        assert!((tr[0].0 - 2.0).abs() < 1e-9);
        assert_eq!(tr[1].1, 90.0);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut m = Metrics::new();
        m.record(1, &report(1.0, 10.0), Some(5.0), None);
        let csv = m.to_csv();
        assert!(csv.starts_with("batch,"));
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.contains("5.000"));
    }

    #[test]
    fn csv_rows_match_header_column_count() {
        let mut m = Metrics::new();
        m.record(1, &report(1.0, 10.0), None, None);
        let shift = ShiftEvent {
            batch: 2,
            direction: ShiftDirection::Down,
            score: 9.25,
        };
        m.record(2, &report(1.0, 10.0), Some(5.0), Some(shift));
        let csv = m.to_csv();
        let n_cols = csv.lines().next().unwrap().split(',').count();
        for row in csv.lines().skip(1) {
            assert_eq!(row.split(',').count(), n_cols, "row {row:?}");
        }
    }

    #[test]
    fn csv_round_trips_and_tolerates_extra_columns() {
        let mut m = Metrics::new();
        m.record(1, &report(1.0, 10.0), None, None);
        let shift = ShiftEvent {
            batch: 2,
            direction: ShiftDirection::Down,
            score: 9.25,
        };
        m.record(2, &report(2.0, 20.0), Some(5.0), Some(shift));
        let parsed = Metrics::parse_csv(&m.to_csv()).unwrap();
        assert_eq!(parsed.records.len(), 2);
        assert_eq!(parsed.records[0].index, 1);
        assert!(parsed.records[0].shift.is_none());
        let s = parsed.records[1].shift.expect("shift survives round trip");
        assert_eq!(s.direction, ShiftDirection::Down);
        assert!((s.score - 9.25).abs() < 1e-9);
        assert!((parsed.total_tokens - 30.0).abs() < 1e-9);

        // A FUTURE writer appending more columns must not break this
        // parser (the append-only contract).
        let extended: String = m
            .to_csv()
            .lines()
            .enumerate()
            .map(|(i, l)| {
                if i == 0 {
                    format!("{l},future_metric\n")
                } else {
                    format!("{l},1.5\n")
                }
            })
            .collect();
        let parsed = Metrics::parse_csv(&extended).unwrap();
        assert_eq!(parsed.records.len(), 2);
        assert!(parsed.records[1].shift.is_some());

        // And a PAST writer without the shift/byte columns still parses
        // (missing optional columns read as None/0).
        let legacy = "batch,inner_iters,seconds,tokens,train_ppx,elapsed,eval_ppx\n\
                      1,3,1.000000,10,2.718,1.000000,\n\
                      2,3,1.000000,10,2.718,2.000000,5.000\n";
        let parsed = Metrics::parse_csv(legacy).unwrap();
        assert_eq!(parsed.records.len(), 2);
        assert!(parsed.records[0].shift.is_none());
        assert_eq!(parsed.records[1].eval_perplexity, Some(5.0));
        assert_eq!(parsed.records[1].resp_bytes, 0);
    }
}
