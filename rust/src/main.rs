//! `foem` — command-line entry point for the Fast Online EM topic
//! modeling system.
//!
//! Subcommands:
//!   train      train an algorithm on a corpus (UCI docword file or a
//!              synthetic profile) and report predictive perplexity
//!   info       show artifact registry + build info
//!   selftest   run the PJRT artifact smoke test (L1/L2/L3 composition)
//!
//! Examples:
//!   foem train --corpus synth:pubmed --algorithm foem --k 100
//!   foem train --corpus data/docword.enron.txt --algorithm ovb --ds 512
//!   foem train --corpus synth:nytimes --algorithm foem \
//!        --store-path /tmp/phi.bin --buffer-mb 64 --verbose true
//!   foem train --corpus synth:pubmed --algorithm foem --store-path /tmp/phi.bin \
//!        --buffer-mb 64 --pipeline-depth 2 --n-workers 4
//!   foem train --corpus synth:nytimes --algorithm foem --store-path /tmp/phi.bin \
//!        --buffer-mb 64 --checkpoint-dir /tmp/ckpt --checkpoint-every 50
//!   foem train --corpus synth:nytimes --algorithm foem --store-path /tmp/phi.bin \
//!        --buffer-mb 64 --checkpoint-dir /tmp/ckpt --resume true
//!   foem info

use anyhow::{Context, Result};
use foem::coordinator::config::RunConfig;
use foem::coordinator::driver::Driver;
use foem::corpus::synthetic::{self, SyntheticConfig};
use foem::corpus::{uci, Corpus};

fn usage() -> ! {
    eprintln!(
        "usage: foem <train|info|selftest> [--key value ...]\n\
         train keys: --corpus <synth:NAME|PATH> --algorithm <foem|sem|scvb|ovb|ogs|rvb|soi>\n\
         \x20       --k N --ds N --passes N --seed N --eval-every N --verbose true\n\
         \x20       --store-path PATH --buffer-mb N --lambda-k-topics N --config FILE\n\
         \x20       --phi-codec <raw|sparse|rle|auto>  (paged-store column\n\
         \x20                            encoding; all lossless — auto picks the\n\
         \x20                            smallest per column, raw is the\n\
         \x20                            bit-identity reference format)\n\
         \x20       --n-workers N  (parallel sharded E-step; 1 = serial)\n\
         \x20       --shards N  (vocabulary-sharded store fleet: N owner\n\
         \x20                            threads, each with its own paged store\n\
         \x20                            pair + WAL + checkpoint; 0 = single\n\
         \x20                            store, 1 = bit-identical to unsharded;\n\
         \x20                            foem + --store-path only)\n\
         \x20       --pipeline-depth N  (software-pipelined staging: prefetch +\n\
         \x20                            write-behind overlap compute; 0 = off,\n\
         \x20                            bit-identical serial; foem/sem only)\n\
         \x20       --fold-in-subset N  (topics per doc scheduled by the eval\n\
         \x20                            fold-in engine; 0 = all K dense)\n\
         \x20       --fold-in-workers N  (parallel fold-in over doc shards)\n\
         \x20       --kernel-backend <scalar|simd|auto>  (E-step kernel tier:\n\
         \x20                            scalar = bit-exact reference, simd =\n\
         \x20                            AVX2/portable vector tier, auto =\n\
         \x20                            AVX2 when detected else scalar)\n\
         \x20       --checkpoint-dir PATH  (atomic trainer snapshots every\n\
         \x20                            --checkpoint-every N batches; arms the\n\
         \x20                            paged-store write-ahead log so a kill at\n\
         \x20                            any point is recoverable)\n\
         \x20       --resume true  (continue a crashed run from\n\
         \x20                            --checkpoint-dir: replays WAL-committed\n\
         \x20                            batches, then resumes the stream —\n\
         \x20                            bit-identical to the uninterrupted run)\n\
         \x20       --wal true  (arm the write-ahead log without checkpoints)\n\
         \x20       --drift-detector <off|cusum|window>  (shift monitor over\n\
         \x20                            per-batch train log-likelihood; off =\n\
         \x20                            default, bit-identical; cusum = two-sided\n\
         \x20                            standardized CUSUM; window = plain z-test)\n\
         \x20       --drift-response <none|decay-reset|widen|grow>  (what an\n\
         \x20                            alarm triggers: none = telemetry only,\n\
         \x20                            decay-reset = discount sufficient stats,\n\
         \x20                            widen = full-K fold-in exploration, grow =\n\
         \x20                            add --drift-grow-topics new topics; foem +\n\
         \x20                            pipeline-depth 0 only, grow needs the\n\
         \x20                            in-memory store)\n\
         \x20       --drift-threshold H --drift-slack K  (CUSUM alarm level and\n\
         \x20                            per-batch slack; defaults 8.0 / 2.0 —\n\
         \x20                            see rust/DESIGN.md \u{a7}15 for the tuning\n\
         \x20                            argument)\n\
         \x20       --drift-window N --drift-warmup N  (rolling baseline size\n\
         \x20                            and post-reset cooldown; defaults 16 / 12)\n\
         \x20       --serve-* keys  (serving layer policy for embedders that\n\
         \x20                        attach a serve::ModelRegistry; `foem train`\n\
         \x20                        itself starts no server — see the serve\n\
         \x20                        module docs and examples/serve_stream.rs)"
    );
    std::process::exit(2);
}

/// Parse `--key value` pairs into (key, value) with `-` normalized to `_`.
fn parse_flags(args: &[String]) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .with_context(|| format!("expected --flag, got {}", args[i]))?;
        let value = args
            .get(i + 1)
            .with_context(|| format!("--{key} needs a value"))?;
        out.push((key.replace('-', "_"), value.clone()));
        i += 2;
    }
    Ok(out)
}

fn load_corpus(spec: &str, seed: u64) -> Result<Corpus> {
    if let Some(name) = spec.strip_prefix("synth:") {
        let cfg = match name {
            "small" => SyntheticConfig::small(),
            "nips" => SyntheticConfig::nips_like(),
            "enron" => SyntheticConfig::enron_like(),
            "wiki" => SyntheticConfig::wiki_like(),
            "nytimes" => SyntheticConfig::nytimes_like(),
            "pubmed" => SyntheticConfig::pubmed_like(),
            other => anyhow::bail!(
                "unknown synthetic profile {other} \
                 (small|nips|enron|wiki|nytimes|pubmed)"
            ),
        };
        Ok(synthetic::generate(&cfg, seed))
    } else {
        uci::load_docword(std::path::Path::new(spec))
    }
}

fn cmd_train(args: &[String]) -> Result<()> {
    let flags = parse_flags(args)?;
    let mut cfg = RunConfig::default();
    let mut corpus_spec = String::from("synth:small");
    // config file first, CLI overrides second
    for (k, v) in &flags {
        if k == "config" {
            cfg = RunConfig::from_file(std::path::Path::new(v))?;
        }
    }
    for (k, v) in &flags {
        match k.as_str() {
            "config" => {}
            "corpus" => corpus_spec = v.clone(),
            other => cfg.set(other, v).with_context(|| format!("--{k}"))?,
        }
    }

    let corpus = load_corpus(&corpus_spec, cfg.seed)?;
    println!(
        "corpus {}: D={} W={} NNZ={} tokens={}",
        corpus.name,
        corpus.n_docs(),
        corpus.n_words(),
        corpus.nnz(),
        corpus.n_tokens()
    );
    println!(
        "algorithm {} K={} D_s={} workers={} pipeline_depth={} shards={} \
         store={:?}",
        cfg.algorithm.name(),
        cfg.n_topics,
        cfg.minibatch_docs,
        cfg.n_workers,
        cfg.pipeline_depth,
        cfg.n_shards,
        cfg.store
    );
    let mut driver = Driver::new(cfg);
    let report = driver.train_corpus(&corpus)?;
    println!(
        "done: predictive perplexity {:.2} | {:.0} tokens/s | mean inner iters {:.1}",
        report.final_perplexity,
        report.metrics.tokens_per_second(),
        report.metrics.mean_inner_iters()
    );
    if let Some(io) = report.io {
        println!(
            "store I/O: {} col reads, {} col writes, {} buffer hits, {} misses",
            io.col_reads, io.col_writes, io.buffer_hits, io.buffer_misses
        );
        if io.prefetched_cols + io.prefetch_hits + io.wb_writes > 0 {
            println!(
                "overlapped I/O: {} cols prefetched, {} prefetch hits, \
                 {} write-behind flushes",
                io.prefetched_cols, io.prefetch_hits, io.wb_writes
            );
        }
        if io.logical_bytes > 0 {
            println!(
                "store bytes: {} logical -> {} on disk ({:.2}x compression)",
                io.logical_bytes,
                io.disk_bytes,
                io.logical_bytes as f64 / io.disk_bytes.max(1) as f64
            );
        }
    }
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("foem {} — Fast Online EM for big topic modeling", env!("CARGO_PKG_VERSION"));
    let dir = std::path::Path::new("artifacts");
    match foem::runtime::registry::Registry::load(dir) {
        Ok(reg) => {
            println!("artifacts ({}):", reg.len());
            for a in reg.iter() {
                println!(
                    "  {} [{}] b={} k={}{}",
                    a.name,
                    a.graph,
                    a.b,
                    a.k,
                    if a.graph == "sem" {
                        format!(" ds={} ws={} iters={}", a.ds, a.ws, a.iters)
                    } else {
                        String::new()
                    }
                );
            }
        }
        Err(e) => println!("no artifacts: {e:#}"),
    }
    Ok(())
}

fn cmd_selftest() -> Result<()> {
    // Compose L3 (this binary) with the AOT L2/L1 artifact through PJRT
    // and check the numerics against the native Rust E-step.
    let dir = std::path::Path::new("artifacts");
    let mut exec = foem::runtime::Executor::new(dir)?;
    let meta = exec
        .estep_variant_for(64)
        .context("no estep artifact with k >= 64")?;
    println!("selftest: executing {} via PJRT", meta.name);
    let (b, k) = (meta.b, meta.k);
    let mut rng = foem::util::Rng::new(0);
    let theta: Vec<f32> = (0..b * k).map(|_| rng.next_f32() * 5.0).collect();
    let phi: Vec<f32> = (0..b * k).map(|_| rng.next_f32() * 3.0).collect();
    let phisum: Vec<f32> = (0..k).map(|_| rng.next_f32() * 100.0 + 1.0).collect();
    let counts: Vec<f32> = (0..b).map(|_| (rng.below(5) + 1) as f32).collect();
    let (am1, bm1, wbm1) = (0.01f32, 0.01f32, 0.01f32 * 5000.0);
    let out = exec.run_estep(&meta.name, &theta, &phi, &phisum, &counts, am1, bm1, wbm1)?;

    // Native reference.
    let mut max_err = 0f32;
    let mut mu = vec![0.0f32; k];
    for e in 0..b {
        let z = foem::em::estep_unnormalized(
            &theta[e * k..(e + 1) * k],
            &phi[e * k..(e + 1) * k],
            &phisum,
            am1,
            bm1,
            wbm1,
            &mut mu,
        );
        let inv = 1.0 / z;
        for i in 0..k {
            let want = mu[i] * inv;
            max_err = max_err.max((out.mu[e * k + i] - want).abs());
        }
    }
    println!("selftest: max |PJRT - native| = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-4, "numerics mismatch");
    println!("selftest OK");
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args[1..]),
        Some("info") => cmd_info(),
        Some("selftest") => cmd_selftest(),
        _ => usage(),
    }
}
