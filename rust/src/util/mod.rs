//! Small shared utilities: a deterministic RNG wrapper, timing helpers,
//! and numeric helpers used across modules.

use std::time::Instant;

/// Deterministic xoshiro256++ PRNG.
///
/// Every stochastic component in the crate (synthetic corpora, random
/// initializations, Gibbs sampling, minibatch shuffling) seeds one of
/// these so that experiments are exactly reproducible run-to-run; `rand`'s
/// `StdRng` is not stable across crate versions, which would silently
/// change recorded experiment numbers.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64, per the xoshiro reference implementation.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Export the raw xoshiro256++ state — the crash-recovery checkpoint
    /// persists this so a resumed run continues the exact draw sequence
    /// (`coordinator::checkpoint`).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild an RNG mid-stream from a [`Self::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n), via Lemire's multiply-shift with
    /// rejection (*Fast Random Integer Generation in an Interval*). The
    /// historical `next_u64() % n` had modulo bias: values below
    /// `2^64 mod n` were ~`n / 2^64` more likely — negligible per draw
    /// but systematic across the billions of topic draws a big run makes.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut m = (self.next_u64() as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            // Rejection threshold 2^64 mod n, computed without u128
            // division as (-n) mod n.
            let t = n.wrapping_neg() % n;
            while lo < t {
                m = (self.next_u64() as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box-Muller (cached half dropped for simplicity).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Gamma(shape, 1) via Marsaglia-Tsang; shape > 0.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.next_f64().max(1e-12);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.next_f64().max(1e-12);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Sample from a symmetric Dirichlet(conc) of dimension `dim`.
    pub fn dirichlet_sym(&mut self, conc: f64, dim: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..dim).map(|_| self.gamma(conc)).collect();
        let s: f64 = v.iter().sum();
        let s = if s > 0.0 { s } else { 1.0 };
        for x in &mut v {
            *x /= s;
        }
        v
    }

    /// Poisson(lambda) via Knuth (small lambda) / normal approx (large).
    pub fn poisson(&mut self, lambda: f64) -> usize {
        if lambda > 64.0 {
            let x = lambda + lambda.sqrt() * self.normal();
            return x.max(0.0).round() as usize;
        }
        let l = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut r = self.next_f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// A self-cleaning temporary directory (replacement for the `tempfile`
/// crate, which is not in the vendored dependency set).
pub struct TempDir {
    path: std::path::PathBuf,
}

impl TempDir {
    pub fn new(label: &str) -> Self {
        use std::sync::atomic::{AtomicU64, Ordering};
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let pid = std::process::id();
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos())
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!("foem-{label}-{pid}-{n}-{nanos}"));
        std::fs::create_dir_all(&path).expect("create temp dir");
        Self { path }
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// A simple stopwatch for the experiment harness.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn seconds(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Default for Timer {
    fn default() -> Self {
        Self::start()
    }
}

/// Minimal micro-benchmark runner (the vendored crate set has no
/// criterion). Warms up, then runs timed batches until `budget` elapses,
/// reporting mean / p50 / p95 per-iteration times like criterion's
/// summary line. Used by `rust/benches/*` (harness = false).
pub mod bench {
    use std::time::{Duration, Instant};

    pub struct Report {
        pub name: String,
        pub iters: u64,
        pub mean_ns: f64,
        pub p50_ns: f64,
        pub p95_ns: f64,
    }

    impl Report {
        pub fn print(&self) {
            println!(
                "{:<44} {:>10} iters   mean {:>12}   p50 {:>12}   p95 {:>12}",
                self.name,
                self.iters,
                fmt_ns(self.mean_ns),
                fmt_ns(self.p50_ns),
                fmt_ns(self.p95_ns)
            );
        }
    }

    pub fn fmt_ns(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.1}ns")
        } else if ns < 1e6 {
            format!("{:.2}µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2}ms", ns / 1e6)
        } else {
            format!("{:.3}s", ns / 1e9)
        }
    }

    /// Benchmark `f`, spending roughly `budget` on measurement.
    pub fn run<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> Report {
        // Warmup: at least 3 runs or 10% of budget.
        let warm_until = Instant::now() + budget / 10;
        let mut warm_runs = 0;
        while warm_runs < 3 || Instant::now() < warm_until {
            f();
            warm_runs += 1;
        }
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < budget || samples.len() < 5 {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
            if samples.len() >= 10_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        let report = Report {
            name: name.to_string(),
            iters: samples.len() as u64,
            mean_ns: mean,
            p50_ns: p(0.5),
            p95_ns: p(0.95),
        };
        report.print();
        report
    }

    /// Prevent the optimizer from deleting a computed value.
    #[inline]
    pub fn black_box<T>(x: T) -> T {
        std::hint::black_box(x)
    }
}

/// One 32-byte-aligned block of eight `f32` lanes.
#[derive(Debug, Clone, Copy, Default)]
#[repr(C, align(32))]
struct Lanes([f32; 8]);

/// A growable `f32` buffer whose backing storage is 32-byte aligned —
/// the allocation contract the AVX2 E-step tier (`em::simd`) relies on
/// for its hot loads. Semantically a `Vec<f32>`: derefs to `[f32]`,
/// `resize` has `Vec::resize` fill semantics (every index past the old
/// logical length reads the fill value, even after a `clear` left stale
/// floats in a partially used lane), and capacity is grow-only so
/// steady-state reuse allocates nothing. Alignment is structural
/// (`repr(align(32))` lanes), so it survives every grow/realloc.
#[derive(Debug, Clone, Default)]
pub struct AlignedF32 {
    data: Vec<Lanes>,
    len: usize,
}

impl AlignedF32 {
    pub fn new() -> Self {
        Self::default()
    }

    /// Logical length 0; lane capacity (and stale contents) retained.
    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// `Vec::resize(new_len, value)` semantics on the logical prefix.
    pub fn resize(&mut self, new_len: usize, value: f32) {
        let lanes = new_len.div_ceil(8);
        if new_len > self.len {
            self.data.resize(lanes, Lanes([value; 8]));
            let old = self.len;
            self.len = new_len;
            // Lanes recycled from an earlier, longer life still hold
            // stale floats; the explicit fill restores Vec semantics.
            self[old..new_len].fill(value);
        } else {
            self.data.truncate(lanes);
            self.len = new_len;
        }
    }
}

impl std::ops::Deref for AlignedF32 {
    type Target = [f32];

    #[inline]
    fn deref(&self) -> &[f32] {
        // SAFETY: `data` stores `len.div_ceil(8)` fully initialized
        // `[f32; 8]` blocks laid out contiguously (`repr(C)`), so the
        // first `len` floats are initialized and in bounds. An empty
        // Vec's dangling pointer is aligned and valid for length 0.
        unsafe { std::slice::from_raw_parts(self.data.as_ptr() as *const f32, self.len) }
    }
}

impl std::ops::DerefMut for AlignedF32 {
    #[inline]
    fn deref_mut(&mut self) -> &mut [f32] {
        // SAFETY: as in `deref`, plus exclusive access via `&mut self`.
        unsafe { std::slice::from_raw_parts_mut(self.data.as_mut_ptr() as *mut f32, self.len) }
    }
}

/// `log(sum_i exp(x_i))` without overflow — used by the VB baselines.
pub fn log_sum_exp(xs: &[f32]) -> f32 {
    let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f32>().ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_is_in_range_and_unbiased() {
        let mut r = Rng::new(2);
        // Range check across sizes, including non-powers-of-two.
        for &n in &[1usize, 2, 3, 7, 10, 1000, 1 << 20] {
            for _ in 0..200 {
                assert!(r.below(n) < n);
            }
        }
        // Uniformity: loose chi-square-ish bound over a small modulus.
        // With 60k draws over 6 buckets each expects 10k, σ ≈ 91; 500
        // is ~5.5σ — the deterministic stream sits far inside it.
        let mut hits = [0usize; 6];
        for _ in 0..60_000 {
            hits[r.below(6)] += 1;
        }
        for (i, &h) in hits.iter().enumerate() {
            assert!(
                (h as f64 - 10_000.0).abs() < 500.0,
                "bucket {i} skewed: {h}"
            );
        }
        // Deterministic given the seed (rejection consumes a variable
        // number of raw draws, but the same ones every run).
        let mut a = Rng::new(99);
        let mut b = Rng::new(99);
        for &n in &[3usize, 1 << 33, 5, (1 << 62) + 3] {
            assert_eq!(a.below(n), b.below(n));
        }
    }

    #[test]
    fn rng_f32_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(3);
        for &dim in &[2usize, 10, 100] {
            let v = r.dirichlet_sym(0.1, dim);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "{s}");
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn gamma_mean_approx() {
        let mut r = Rng::new(5);
        let shape = 2.5;
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
        assert!((mean - shape).abs() < 0.1, "{mean}");
    }

    #[test]
    fn poisson_mean_approx() {
        let mut r = Rng::new(6);
        for &lam in &[3.0, 120.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((mean - lam).abs() < lam * 0.05, "lam={lam} mean={mean}");
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(8);
        let w = [1.0f32, 0.0, 3.0];
        let mut hits = [0usize; 3];
        for _ in 0..40_000 {
            hits[r.categorical(&w)] += 1;
        }
        assert_eq!(hits[1], 0);
        let ratio = hits[2] as f64 / hits[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "{ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn aligned_f32_is_32_byte_aligned_across_growth() {
        let mut a = AlignedF32::new();
        assert_eq!(a.len(), 0);
        for &n in &[1usize, 7, 8, 9, 64, 1000, 4096] {
            a.resize(n, 0.5);
            assert_eq!(a.len(), n);
            assert_eq!(a.as_ptr() as usize % 32, 0, "misaligned at len {n}");
            assert!(a.iter().all(|&x| x == 0.5), "fill broken at len {n}");
            a.iter_mut().for_each(|x| *x = 9.0);
        }
    }

    #[test]
    fn aligned_f32_resize_has_vec_fill_semantics() {
        // The hazard: clear + regrow must not expose stale floats from a
        // partially used final lane.
        let mut a = AlignedF32::new();
        a.resize(13, 7.0);
        a.clear();
        a.resize(5, 1.0);
        assert!(a.iter().all(|&x| x == 1.0), "stale data after clear");
        // Growing within the same lane must fill the gap too.
        a.resize(13, 2.0);
        assert_eq!(&a[..5], &[1.0; 5]);
        assert_eq!(&a[5..13], &[2.0; 8]);
        // Shrink then regrow across the lane boundary.
        a.resize(3, 0.0);
        a.resize(20, 4.0);
        assert_eq!(&a[..3], &[1.0, 1.0, 1.0]);
        assert!(a[3..].iter().all(|&x| x == 4.0));
        let mut v: Vec<f32> = vec![7.0; 13];
        v.clear();
        v.resize(5, 1.0);
        v.resize(13, 2.0);
        v.resize(3, 0.0);
        v.resize(20, 4.0);
        assert_eq!(&a[..], &v[..], "diverged from Vec::resize semantics");
    }

    #[test]
    fn log_sum_exp_matches_naive() {
        let xs = [0.1f32, -2.0, 3.5];
        let naive = xs.iter().map(|x| x.exp()).sum::<f32>().ln();
        assert!((log_sum_exp(&xs) - naive).abs() < 1e-5);
    }
}
