#!/usr/bin/env python3
"""Compare fresh bench output against the committed BENCH_*.json baselines.

Usage:
    python3 scripts/bench_gate.py --baseline-dir <dir> --fresh-dir <dir> \
        [--threshold 0.20]

Each BENCH_*.json file is a sequence of JSON lines as emitted by the
benches in rust/benches/ (and collected by scripts/bench.sh). Rows are
keyed on their identity fields (bench, k, subset, impl, workers, depth,
algo, isa, codec, sweep, wal, shards) and compared on the metrics of the
file's bench family:

    BENCH_estep.json     estep_kernel         mean_ns        lower is better
    BENCH_foldin.json    foldin               mean_ns        lower is better
    BENCH_pipeline.json  streaming_pipeline   tokens_per_sec higher is better
                                              disk_bytes     lower is better
                                              file_bytes     lower is better
    BENCH_serve.json     serve                docs_per_sec   higher is better
    BENCH_drift.json     drift                detection_latency_batches,
                                              post_shift_recovery_batches,
                                              false_alarms   lower is better

The drift metrics are batch counts from a fully seeded run (no timing),
so they are exactly reproducible; zero-valued baselines (e.g. the
stationary control's false_alarms) are skipped by the degenerate-value
guard below and pinned by `tests/drift_equivalence.rs` instead.

The byte metrics gate the paged store's compression trajectory (column
codecs, rust/DESIGN.md §12) exactly like the timing metrics gate
throughput: a codec or allocator change that inflates real disk traffic
(disk_bytes) or the backing file's data size (file_bytes) beyond the
threshold fails. Rows that don't carry a given metric — e.g. timing-only
rows predating the byte counters, on either side — are skipped silently
for that metric, so refreshed baselines phase new metrics in without
churn.

Summary rows (bench == "*_summary") are informational and skipped.

A matched row regressing beyond the threshold (default ±20%) fails the
gate (exit 1). Baseline rows with no fresh counterpart — e.g. the
"isa":"avx2" SIMD rows when the bench host has no AVX2 and reports a
different ISA — only warn, so the gate stays meaningful on heterogeneous
runners. Fresh rows with no baseline are reported as new.

Baselines are a committed perf trajectory, not a promise about absolute
wall-clock on any given host: refresh them by running scripts/bench.sh on
the CI runner class and committing the regenerated BENCH_*.json files.
The initial baselines were seeded as estimates before the first CI run,
so the first refresh from a real runner supersedes them wholesale. The
CI job that runs this gate is non-blocking (continue-on-error) for
exactly that reason; the blocking correctness coverage for the kernel
tiers lives in `cargo test backend_` instead.
"""

import argparse
import json
import os
import sys

# file -> (bench tag, [(metric, higher_is_better), ...])
FAMILIES = {
    "BENCH_estep.json": ("estep_kernel", [("mean_ns", False)]),
    "BENCH_foldin.json": ("foldin", [("mean_ns", False)]),
    "BENCH_pipeline.json": ("streaming_pipeline", [
        ("tokens_per_sec", True),
        ("disk_bytes", False),
        ("file_bytes", False),
        ("wal_bytes", False),
    ]),
    "BENCH_serve.json": ("serve", [("docs_per_sec", True)]),
    "BENCH_drift.json": ("drift", [
        ("detection_latency_batches", False),
        ("post_shift_recovery_batches", False),
        ("false_alarms", False),
    ]),
}

KEY_FIELDS = ("bench", "k", "subset", "impl", "workers", "depth", "algo",
              "isa", "codec", "sweep", "wal", "shards", "scenario",
              "detector")


def load_rows(path, bench_tag):
    """Parse the JSON lines of one bench file, keyed by identity fields.

    Only rows whose "bench" field matches `bench_tag` participate in the
    gate; summary rows and malformed lines are skipped (malformed lines
    warn — the file is machine-generated, so garbage means a broken run).
    """
    rows = {}
    with open(path, encoding="utf-8") as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError as e:
                print(f"warning: {path}:{ln}: unparseable line ({e})")
                continue
            if row.get("bench") != bench_tag:
                continue
            key = tuple((f, row[f]) for f in KEY_FIELDS if f in row)
            if key in rows:
                print(f"warning: {path}:{ln}: duplicate row key {key}")
            rows[key] = row
    return rows


def fmt_key(key):
    return " ".join(f"{f}={v}" for f, v in key if f != "bench")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-dir", required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh-dir", required=True,
                    help="directory holding freshly generated BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="allowed relative regression (default 0.20 = 20%%)")
    args = ap.parse_args()

    regressions = []
    compared = 0
    for fname, (bench_tag, metrics) in FAMILIES.items():
        base_path = os.path.join(args.baseline_dir, fname)
        fresh_path = os.path.join(args.fresh_dir, fname)
        if not os.path.exists(base_path):
            print(f"warning: no baseline {base_path}; skipping {fname}")
            continue
        if not os.path.exists(fresh_path):
            print(f"warning: no fresh output {fresh_path}; skipping {fname}")
            continue
        base = load_rows(base_path, bench_tag)
        fresh = load_rows(fresh_path, bench_tag)

        for key, brow in sorted(base.items()):
            frow = fresh.pop(key, None)
            if frow is None:
                print(f"warning: {fname}: baseline row unmatched "
                      f"({fmt_key(key)}) — different host class?")
                continue
            matched_any = False
            for metric, higher_better in metrics:
                old, new = brow.get(metric), frow.get(metric)
                if old is None and new is None:
                    # Neither side carries this metric (e.g. byte counters
                    # on timing-only rows): not this row's metric, move on.
                    continue
                if old is None or new is None or old <= 0:
                    print(f"warning: {fname}: missing/degenerate {metric} "
                          f"({fmt_key(key)})")
                    continue
                matched_any = True
                compared += 1
                change = new / old - 1.0
                worse = -change if higher_better else change
                arrow = "better" if worse < 0 else "worse"
                print(f"{fname}: {fmt_key(key)}: {metric} {old:g} -> {new:g} "
                      f"({abs(change) * 100:.1f}% {arrow})")
                if worse > args.threshold:
                    regressions.append(
                        f"{fname}: {fmt_key(key)}: {metric} regressed "
                        f"{worse * 100:.1f}% (old {old:g}, new {new:g})")
            if not matched_any:
                print(f"warning: {fname}: no comparable metric "
                      f"({fmt_key(key)})")
        for key in sorted(fresh):
            print(f"note: {fname}: new row without baseline ({fmt_key(key)})")

    print(f"\nbench gate: {compared} rows compared, "
          f"{len(regressions)} regression(s), "
          f"threshold ±{args.threshold * 100:.0f}%")
    for r in regressions:
        print(f"REGRESSION: {r}")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
