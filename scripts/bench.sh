#!/usr/bin/env bash
# Run the perf-trajectory benches and collect their JSON lines at the
# repo root:
#
#   scripts/bench.sh    # writes BENCH_estep.json + BENCH_pipeline.json
#                       #        + BENCH_foldin.json + BENCH_serve.json
#                       #        + BENCH_drift.json
#
# Each bench prints human-readable summaries to stderr and emits one
# `BENCH_<name>.json {…}` marker line per configuration; this script
# strips the markers into pure JSON-lines files the next PR's numbers
# can be diffed against. A bench that produces NO marker lines is a
# broken emitter, not an empty result — the script fails loudly instead
# of writing an empty file.
set -euo pipefail

root="$(cd "$(dirname "$0")/.." && pwd)"
cd "$root/rust"

run_bench() {
    local bench="$1" out="$2"
    echo ">> cargo bench --bench $bench" >&2
    cargo bench --bench "$bench" \
        | tee /dev/stderr \
        | sed -n "s/^BENCH_${out}\.json //p" >"$root/BENCH_${out}.json"
    if ! [ -s "$root/BENCH_${out}.json" ]; then
        echo "!! bench $bench emitted no BENCH_${out}.json rows" >&2
        rm -f "$root/BENCH_${out}.json"
        exit 1
    fi
    echo ">> wrote $root/BENCH_${out}.json ($(wc -l <"$root/BENCH_${out}.json") rows)" >&2
}

run_bench estep_kernel estep
run_bench streaming_pipeline pipeline
run_bench foldin foldin
run_bench serve serve
run_bench drift drift
